"""Plan/execute GEMM dispatch API tests (the api_redesign acceptance
grid): policy lever selection on the paper's twelve prefill shapes,
plan-cache hit/miss/eviction behavior, bit-exactness of execute vs
kernels/ref in interpret mode, the retired-shim contract, and the
backend registry hook.  Deliberately hypothesis-free — this module must
run on a bare container."""
import time
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import gemm as G
from repro.core import bitexact, packing
from repro.kernels import ref
from repro.models.model_zoo import PAPER_GEMM_SHAPES, PAPER_M

RNG = np.random.default_rng(11)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


@pytest.fixture(autouse=True)
def _fresh_cache():
    G.plan_cache_clear()
    yield
    G.plan_cache_clear()


# ------------------------------------------------------------------ policy
@pytest.mark.parametrize("model,op,n,k", PAPER_GEMM_SHAPES)
def test_policy_levers_on_paper_shapes(model, op, n, k):
    """The acceptance criterion: K >= N resolves to fine panels, N > K to
    pre-packed plans — per shape, not per process."""
    p = G.plan(PAPER_M, n, k)
    if k >= n:
        assert p.lever == G.LEVER_FINE_PANELS, (model, op, p)
        assert not p.prepack
        assert p.pack == G.PACK_PERCALL
    else:
        assert p.lever == G.LEVER_PREPACK, (model, op, p)
        assert p.prepack
        assert p.pack == G.PACK_PREPACKED
        assert p.block_k >= 512          # the deep-K (Kc=2048 class) pack


def test_fine_panels_sized_for_occupancy():
    """K >= N plans feed all cores when the shape allows it (the paper's
    idle-second-block failure, avoided)."""
    p = G.plan(128, 2048, 2048, num_cores=8)
    panels = p.grid[0] * p.grid[1]
    assert panels >= 8 and p.occupancy == 1.0
    coarse = G.plan(128, 2048, 2048, num_cores=8, block_n=1024)
    assert panels > coarse.grid[0] * coarse.grid[1]
    assert p.t_pred < coarse.t_pred


def test_plan_is_static_hashable_pytree():
    p = G.plan(128, 256, 512)
    assert jax.tree_util.tree_leaves(p) == []        # no array leaves
    assert hash(p) == hash(G.plan(128, 256, 512))
    with pytest.raises(Exception):
        object.__setattr__  # frozen: direct assignment raises
        p.backend = "other"  # type: ignore[misc]


# ------------------------------------------------------------- plan cache
def test_plan_cache_hit_miss():
    info0 = G.plan_cache_info()
    assert (info0.hits, info0.misses, info0.currsize) == (0, 0, 0)
    p1 = G.plan(128, 2048, 2048)
    assert G.plan_cache_info().misses == 1
    p2 = G.plan(128, 2048, 2048)
    assert G.plan_cache_info().hits == 1
    assert p2 is p1                       # cached object, not a rebuild
    G.plan(128, 2048, 4096)               # different shape -> miss
    assert G.plan_cache_info().misses == 2
    G.plan(128, 2048, 2048, backend="interpret")   # key includes backend
    assert G.plan_cache_info().misses == 3
    G.plan(64, 2048, 2048)                # key includes m
    assert G.plan_cache_info().misses == 4


def test_plan_cache_keyed_on_sharding_and_dtype():
    a = G.plan(128, 256, 512, dtype=jnp.float32)
    b = G.plan(128, 256, 512, dtype=jnp.bfloat16)
    assert b is not a
    c = G.plan(128, 256, 512, dtype=jnp.float32, sharding="model:0")
    assert c is not a and c.sharding_key == "model:0"
    assert G.plan_cache_info().misses == 3


def test_plan_cache_eviction_bounded():
    from repro.gemm import policy as pol
    for i in range(pol._CACHE_MAXSIZE + 10):
        G.plan(8, 128, 128 * (i + 1), block_n=128, block_k=128)
    assert G.plan_cache_info().currsize <= pol._CACHE_MAXSIZE


# ------------------------------------------------- execute / bit-exactness
@pytest.mark.parametrize("m,n,k", [
    (128, 256, 256), (128, 512, 128), (256, 128, 384),
    (128, 2048 // 4, 2048 // 4),   # scaled QKV class (K >= N)
    (128, 8192 // 16, 2048 // 8),  # scaled FFN1 (N > K)
    (128, 2048 // 8, 8192 // 16),  # scaled FFN2 (K > N)
])
def test_execute_interpret_bitexact_vs_ref(m, n, k):
    """execute() on the interpret backend is BIT-identical to the blocked
    oracle at the plan's block_k — packed and per-call operands alike."""
    x, w = _rand((m, k)), _rand((k, n))
    p = G.plan(m, n, k, backend="interpret", block_m=128, block_n=128,
               block_k=min(128, k), validate=True)
    assert p.validated
    y_percall = G.execute(p, x, w)
    pw = G.pack_for_plan(p, w)
    y_packed = G.execute(p, x, pw)
    oracle = ref.gemm_blocked(x, w, p.block_k)
    bitexact.assert_bit_identical(np.asarray(y_percall), np.asarray(oracle))
    bitexact.assert_bit_identical(np.asarray(y_packed), np.asarray(oracle))


def test_execute_policy_plans_bitexact_both_levers():
    """Policy-resolved (not hand-blocked) plans for one K>=N and one N>K
    shape, interpret backend, against the XLA reference (allclose) and
    each other's pack variants (bitwise)."""
    for (m, n, k) in [(128, 256, 512), (128, 640, 256)]:
        x, w = _rand((m, k)), _rand((k, n))
        p = G.plan(m, n, k, backend="interpret")
        assert G.validate_plan(p)
        pw = G.pack_for_plan(p, w)
        y1, y2 = G.execute(p, x, w), G.execute(p, x, pw)
        bitexact.assert_bit_identical(np.asarray(y1), np.asarray(y2))
        np.testing.assert_allclose(y1, ref.gemm_xla(x, w),
                                   rtol=1e-4, atol=1e-4)


def test_execute_batched_leading_dims_and_mismatch_errors():
    x = _rand((2, 64, 384))
    w = _rand((384, 256))
    p = G.plan(128, 256, 384, backend="xla")
    y = G.execute(p, x, w)
    np.testing.assert_allclose(
        y, np.einsum("bsk,kn->bsn", np.asarray(x), np.asarray(w)),
        rtol=1e-4, atol=1e-4)
    with pytest.raises(G.PlanMismatchError):
        G.execute(p, _rand((64, 384)), w)          # M != plan.m
    with pytest.raises(G.PlanMismatchError):
        G.execute(G.plan(128, 256, 512), _rand((2, 64, 384)), w)  # K
    pw_other = packing.pack(w, block_n=256, block_k=384)
    with pytest.raises(G.PlanMismatchError):
        G.execute(p, x, pw_other)                  # pack blocks != plan


def test_pack_none_skips_relayout_on_xla():
    """The raw-dot analogue: PACK_NONE + xla backend must equal the plain
    XLA dot bitwise (no padding, no re-layout in the way)."""
    x, w = _rand((100, 300)), _rand((300, 200))
    p = G.plan(100, 200, 300, backend="xla", pack=G.PACK_NONE)
    bitexact.assert_bit_identical(
        np.asarray(G.execute(p, x, w)), np.asarray(ref.gemm_xla(x, w)))


# -------------------------------------------- retired legacy shims
def test_legacy_shim_import_raises_with_pointer():
    """The core/panel_gemm shims completed their deprecation timeline:
    importing the module is now a HARD error carrying the migration
    pointer, and repro.core no longer re-exports the legacy names."""
    import repro.core as core
    with pytest.raises(ImportError, match="repro.gemm"):
        import repro.core.panel_gemm  # noqa: F401
    for name in ("gemm", "gemm_percall", "gemm_xla"):
        assert not hasattr(core, name)


def test_env_var_never_steers_a_plan(monkeypatch):
    """REPRO_GEMM_IMPL died with the shims: no surface reads it."""
    monkeypatch.setenv("REPRO_GEMM_IMPL", "interpret")
    p = G.plan(8, 128, 128)
    assert p.backend == "xla"                      # process default wins


# --------------------------------------------------------- backend registry
def test_register_backend_hook():
    calls = []

    def run(x_p, w_p, *, block_m, block_n, block_k, out_dtype):
        calls.append((x_p.shape, w_p.shape))
        return jnp.dot(x_p, w_p,
                       preferred_element_type=jnp.float32).astype(
            out_dtype or x_p.dtype)

    G.register_backend("test-custom", run, description="unit-test")
    try:
        assert "test-custom" in G.list_backends()
        x, w = _rand((16, 128)), _rand((128, 128))
        p = G.plan(16, 128, 128, backend="test-custom", block_m=16,
                   block_n=128, block_k=128)
        y = G.execute(p, x, w)
        assert calls, "custom backend was not dispatched"
        np.testing.assert_allclose(y, ref.gemm_xla(x, w), rtol=1e-5,
                                   atol=1e-5)
        with pytest.raises(ValueError):
            G.register_backend("test-custom", run)   # no silent overwrite
    finally:
        G.unregister_backend("test-custom")
    with pytest.raises(G.UnknownBackendError):
        G.plan(16, 128, 128, backend="test-custom")
    with pytest.raises(ValueError):
        G.unregister_backend("xla")                  # builtins protected


def test_use_backend_scope_nests():
    assert G.default_backend() == "xla"
    with G.use_backend("interpret"):
        assert G.default_backend() == "interpret"
        with G.use_backend("pallas"):
            assert G.default_backend() == "pallas"
        assert G.default_backend() == "interpret"
        assert G.plan(8, 128, 128).backend == "interpret"
    assert G.default_backend() == "xla"
    with G.use_backend(None):                        # optional scope no-op
        assert G.default_backend() == "xla"


# ------------------------------------------- epilogue / fusion bitexact
EPI_SPECS = [
    G.EpilogueSpec(bias=True),
    G.EpilogueSpec(act="silu"),
    G.EpilogueSpec(act="gelu"),
    G.EpilogueSpec(act="tanh"),
    G.EpilogueSpec(softcap=30.0),
    G.EpilogueSpec(residual=True),
    G.EpilogueSpec(bias=True, act="silu", softcap=50.0, residual=True),
    G.EpilogueSpec(glu="silu"),
    G.EpilogueSpec(glu="gelu"),
    G.EpilogueSpec(glu="silu", bias=True, residual=True),
]


def _epi_id(s):
    parts = [k for k, v in (("bias", s.bias), ("res", s.residual)) if v]
    if s.act:
        parts.insert(0, s.act)
    if s.glu:
        parts.insert(0, f"glu-{s.glu}")
    if s.softcap:
        parts.append("softcap")
    return "+".join(parts)


@pytest.mark.parametrize("backend", ["interpret", "xla"])
@pytest.mark.parametrize("spec", EPI_SPECS, ids=_epi_id)
def test_epilogue_bitexact_vs_unfused_sequence(spec, backend):
    """THE fusion contract: for fp32 operands, every EpilogueSpec x
    backend is BIT-identical to the unfused ``execute -> jnp op``
    sequence (ops under jit, as the model runs them)."""
    m, k = 32, 256
    n = 512 if spec.glu else 256
    x, w = _rand((m, k)), _rand((k, n))
    kw = dict(backend=backend, block_m=32, block_n=128, block_k=128)
    base = G.plan(m, n, k, **kw)
    pw = G.pack_for_plan(base, w)
    p = G.plan(m, n, k, epilogue=spec, **kw)
    assert G.validate_plan(p)       # interpret gate covers this spec
    bias = _rand((n,)) if spec.bias else None
    res = _rand((m, p.n_out)) if spec.residual else None

    # both sides under jit — exactly how the model invokes them (jit
    # generates FMAs eager dispatch does not, so eager-vs-jit is NOT
    # bit-stable; jit-vs-jit is the deployed contract)
    @jax.jit
    def fused(x, pw):
        return G.execute(p, x, pw, bias=bias, residual=res)

    @jax.jit
    def unfused(x, pw):
        acc = G.execute(base, x, pw, out_dtype=jnp.float32)
        return G.apply_epilogue(acc, spec, bias=bias,
                                residual=res).astype(jnp.float32)

    bitexact.assert_bit_identical(np.asarray(fused(x, pw)),
                                  np.asarray(unfused(x, pw)))


@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_fused_qkv_pack_split_matches_separate(backend):
    """Horizontal fusion: one pass over a pack_fused weight, split by the
    static map, bit-identical per part to the separate GEMMs (ragged
    part widths exercise the interior padding)."""
    m, k = 128, 256
    widths = (192, 64, 64)
    parts = [_rand((k, n)) for n in widths]
    x = _rand((m, k))
    pwf = packing.pack_fused(parts, block_n=128, block_k=128)
    assert pwf.n_splits == widths
    assert pwf.data.shape == (256, 512)      # parts padded to 256/128/128
    p = G.plan_for_packed(m, pwf, backend=backend)
    outs = G.split_fused(p, G.execute(p, x, pwf))
    assert tuple(o.shape[-1] for o in outs) == widths
    for out, part in zip(outs, parts):
        pw1 = packing.pack(part, block_n=128, block_k=128)
        p1 = G.plan_for_packed(m, pw1, backend=backend)
        bitexact.assert_bit_identical(np.asarray(out),
                                      np.asarray(G.execute(p1, x, pw1)))


def test_fused_glu_pack_blocks_flow():
    """pack_blocks(epilogue=glu) reserves the two-accumulator VMEM
    footprint, so pack and execute-time plan agree on blocks."""
    n_cat, k = 2 * 2048, 2048
    glu = G.EpilogueSpec(glu="silu")
    bn, bk = G.pack_blocks(n_cat, k, epilogue=glu)
    wg, wu = _rand((k, n_cat // 2)), _rand((k, n_cat // 2))
    pw = packing.pack_fused([wg, wu], block_n=bn, block_k=bk)
    p = G.plan_for_packed(128, pw, epilogue=glu)
    assert (p.block_n, p.block_k) == (pw.block_n, pw.block_k)
    assert p.n_out == n_cat // 2
    from repro.kernels.panel_gemm import VMEM_BUDGET, vmem_bytes
    assert vmem_bytes(p.block_m, p.block_n, p.block_k,
                      epilogue=glu) <= VMEM_BUDGET


def test_fused_plan_rejects_raw_weights_and_bad_operands():
    parts = [_rand((256, 128)), _rand((256, 128))]
    pwf = packing.pack_fused(parts, block_n=128, block_k=128)
    x = _rand((8, 256))
    p = G.plan_for_packed(8, pwf)
    with pytest.raises(G.PlanMismatchError):
        G.execute(p, x, jnp.concatenate(parts, axis=1))   # raw concat
    with pytest.raises(G.PlanMismatchError):
        G.execute(p, x, pwf, bias=_rand((256,)))          # no epilogue
    pglu = G.plan_for_packed(8, pwf, epilogue=G.EpilogueSpec(glu="silu"))
    with pytest.raises(ValueError):
        G.split_fused(pglu, _rand((8, 128)))   # glu combines in-kernel
    with pytest.raises(ValueError):
        G.split_fused(G.plan(8, 128, 256), x)  # no split map


def test_plan_cache_keys_fusion_and_epilogue():
    """Fused / epilogue plans are distinct cache entries, and repeated
    fused planning is a cache hit (plans stay hot under fusion)."""
    a = G.plan(128, 512, 256)
    b = G.plan(128, 512, 256, epilogue=G.EpilogueSpec(act="silu"))
    c = G.plan(128, 512, 256, fused_n_splits=(256, 256))
    assert len({a, b, c}) == 3
    assert G.plan_cache_info().misses == 3
    G.plan(128, 512, 256, epilogue=G.EpilogueSpec(act="silu"))
    assert G.plan_cache_info().hits == 1
    # a no-op epilogue normalizes to the plain plan's key
    assert G.plan(128, 512, 256, epilogue=G.EpilogueSpec()) is a


def test_epilogue_spec_validation():
    with pytest.raises(ValueError):
        G.EpilogueSpec(act="relu")
    with pytest.raises(ValueError):
        G.EpilogueSpec(act="silu", glu="silu")
    assert G.EpilogueSpec().is_noop
    assert not G.EpilogueSpec(softcap=1.0).is_noop


# ------------------------------------------------------- vmem satellite
def test_policy_clamps_blocks_to_vmem_budget():
    """Satellite: an explicit (or fused-wide) block triple that exceeds
    the kernel VMEM budget is shrunk until it fits, and the plan says
    so."""
    from repro.kernels.panel_gemm import VMEM_BUDGET, vmem_bytes
    p = G.plan(128, 4096, 8192, block_n=2048, block_k=4096)
    assert p.vmem_clamped
    assert vmem_bytes(p.block_m, p.block_n, p.block_k) <= VMEM_BUDGET
    assert "vmem_clamped" in p.describe()
    # glu doubles the weight/accumulator tiles: the same explicit triple
    # must clamp harder than the plain plan
    glu = G.EpilogueSpec(glu="silu")
    pg = G.plan(128, 4096, 8192, block_n=2048, block_k=4096, epilogue=glu)
    assert vmem_bytes(pg.block_m, pg.block_n, pg.block_k,
                      epilogue=glu) <= VMEM_BUDGET
    # policy-resolved plans stay un-clamped at sane shapes
    assert not G.plan(128, 2048, 2048).vmem_clamped


# ------------------------------------------- sharding-key satellite fix
def test_plan_for_packed_keys_on_named_sharding():
    """Satellite: packs placed with distinct NamedShardings no longer
    alias one plan entry (the sharding_key='' bug)."""
    import jax.sharding as JS
    dev = jax.devices()[0]
    mesh_a = JS.Mesh(np.array([dev]), ("model",))
    mesh_b = JS.Mesh(np.array([dev]), ("data",))
    w = _rand((256, 128))
    pa = packing.pack(w, block_n=128, block_k=128,
                      sharding=JS.NamedSharding(mesh_a, JS.PartitionSpec()))
    pb = packing.pack(w, block_n=128, block_k=128,
                      sharding=JS.NamedSharding(mesh_b, JS.PartitionSpec()))
    plan_a = G.plan_for_packed(8, pa)
    plan_b = G.plan_for_packed(8, pb)
    assert plan_a.sharding_key and plan_b.sharding_key
    assert plan_a.sharding_key != plan_b.sharding_key
    assert plan_a is not plan_b
    # unplaced packs keep the neutral key (cache behavior unchanged)
    pc = packing.pack(w, block_n=128, block_k=128)
    assert G.plan_for_packed(8, pc).sharding_key == ""


# ------------------------------------------------------------ model path
def test_linear_packed_routes_through_plan_cache():
    from repro.models.layers import linear
    w = _rand((384, 256))
    pw = packing.pack(w, block_n=128, block_k=128)
    x = _rand((4, 32, 384))
    y = linear(x, pw)
    np.testing.assert_allclose(
        y, np.einsum("bsk,kn->bsn", np.asarray(x), np.asarray(w)),
        rtol=1e-4, atol=1e-4)
    assert G.plan_cache_info().misses >= 1
    linear(x, pw)
    assert G.plan_cache_info().hits >= 1


# ----------------------------------------- plan-cache bugfix regressions
def test_vmem_warn_state_evicted_with_plan():
    """Bugfix: ``_vmem_warned`` entries die with their cached plan.

    Before the fix the warn-once set only ever grew: a clamped plan's
    LRU eviction left its warn key behind, so (a) the set leaked
    unboundedly under plan churn and (b) a re-resolved clamp of the
    same shape was silently un-warned forever."""
    from repro.gemm import policy as pol
    p = G.plan(128, 4096, 8192, block_n=2048, block_k=4096)
    assert p.vmem_clamped
    wk = pol._warn_key(p)
    assert wk in pol._vmem_warned
    # churn the cache until the clamped plan is LRU-evicted
    for i in range(pol._CACHE_MAXSIZE + 1):
        G.plan(8, 128, 128 * (i + 1), block_n=128, block_k=128)
    from repro.gemm.policy import _plan_key
    assert _plan_key(128, 4096, 8192, block_n=2048,
                     block_k=4096) not in pol._cache
    assert wk not in pol._vmem_warned     # warn state evicted alongside
    # ...so the NEXT resolution of that shape warns again
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        G.plan(128, 4096, 8192, block_n=2048, block_k=4096)
    assert any("VMEM" in str(w.message) for w in wlog)


def test_vmem_warn_state_kept_while_sibling_cached():
    """A warn key shared by two cached clamped plans (same logical
    shape, different explicit blocks) survives one sibling's eviction —
    warn-once stays once while any holder is live."""
    from repro.gemm import policy as pol
    a = G.plan(128, 4096, 8192, block_n=2048, block_k=4096)
    b = G.plan(128, 4096, 8192, block_n=4096, block_k=2048)
    assert a.vmem_clamped and b.vmem_clamped
    wk = pol._warn_key(a)
    assert pol._warn_key(b) == wk and wk in pol._vmem_warned
    with pol._cache_lock:                 # evict exactly plan ``a``
        ka = next(k for k, v in pol._cache.items() if v is a)
        del pol._cache[ka]
        # simulate the eviction path's warn-state scan
        if not any(q.vmem_clamped and pol._warn_key(q) == wk
                   for q in pol._cache.values()):
            pol._vmem_warned.discard(wk)
    assert wk in pol._vmem_warned         # sibling ``b`` still cached


def test_plan_cache_clear_resets_counters():
    """Bugfix contract: ``plan_cache_clear`` resets entries AND both
    hit/miss counters AND the vmem warn/clamp observability — a cleared
    cache reads (0, 0, maxsize, 0) exactly, so tests and benchmarks
    can treat counter deltas as absolute."""
    from repro.gemm import policy as pol
    G.plan(128, 2048, 2048)
    G.plan(128, 2048, 2048)
    G.plan(128, 4096, 8192, block_n=2048, block_k=4096)  # clamped
    info = G.plan_cache_info()
    assert info.hits == 1 and info.misses == 2 and info.currsize == 2
    assert G.vmem_clamped_count() == 1 and pol._vmem_warned
    G.plan_cache_clear()
    info = G.plan_cache_info()
    assert (info.hits, info.misses, info.currsize) == (0, 0, 0)
    assert info.maxsize == pol._CACHE_MAXSIZE
    assert G.vmem_clamped_count() == 0
    assert not pol._vmem_warned


def test_concurrent_plan_single_resolve(monkeypatch):
    """Bugfix: N threads racing one cold key share ONE resolution.

    Before the per-key in-flight dedup, every racer that read the miss
    before the first writer published ran its own ``_resolve`` — N
    analytic resolutions (and, with validate=True, N bit-exactness gate
    runs) for one plan, and the miss counter over-counted."""
    import threading
    from repro.gemm import policy as pol
    calls = []
    real = pol._resolve

    def counting(*a, **kw):
        calls.append(threading.get_ident())
        time.sleep(0.05)              # widen the race window
        return real(*a, **kw)

    monkeypatch.setattr(pol, "_resolve", counting)
    n_threads = 8
    barrier = threading.Barrier(n_threads)
    plans, errs = [], []

    def racer():
        try:
            barrier.wait()
            plans.append(G.plan(96, 1536, 1536, validate=True))
        except Exception as e:        # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=racer) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert len(calls) == 1, f"{len(calls)} resolves for one key"
    assert len({id(p) for p in plans}) == 1    # all adopted one object
    info = G.plan_cache_info()
    assert info.misses == 1 and info.hits == n_threads - 1


def test_inflight_owner_failure_hands_off(monkeypatch):
    """A failed owner releases its waiters, and one of them becomes the
    new owner instead of caching the failure or deadlocking."""
    import threading
    from repro.gemm import policy as pol
    real = pol._resolve
    fail_first = [True]
    calls = []

    def flaky(*a, **kw):
        calls.append(1)
        time.sleep(0.05)
        if fail_first[0]:
            fail_first[0] = False
            raise RuntimeError("injected resolve failure")
        return real(*a, **kw)

    monkeypatch.setattr(pol, "_resolve", flaky)
    results, errs = [], []
    barrier = threading.Barrier(2)

    def racer():
        try:
            barrier.wait()
            results.append(G.plan(80, 1280, 1280))
        except RuntimeError as e:
            errs.append(e)

    ts = [threading.Thread(target=racer) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(errs) == 1 and "injected" in str(errs[0])
    assert len(results) == 1               # the survivor got a real plan
    assert len(calls) == 2                 # failed owner + take-over
    assert not pol._inflight               # no leaked in-flight events
