"""Paged KV cache in isolation: alloc/free/refill round-trips must equal
a dense [B, max_len] cache on random decode traces (including the wrap
case where a long-running slot outlives several refilled neighbors), and
the allocator must never alias or leak a page."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.models import model_zoo
from repro.runtime import kv_cache as KV

PAGE = 4
MAX_LEN = 16
FEAT = (2, 3)


def _pool(num_slots=3, num_pages=None):
    return KV.PagedKVCache(
        num_layers=1, num_slots=num_slots, max_len=MAX_LEN,
        page_size=PAGE, leaf_specs={"pages_k": (FEAT, jnp.float32)},
        num_pages=num_pages)


def _write(pool, slot, n_new, rng, dense):
    """Write n_new tokens to `slot` through the paged path AND the dense
    reference; returns nothing (mutates both)."""
    start = int(pool.lens[slot])
    pool.alloc(slot, start + n_new)
    vals = rng.standard_normal((1, n_new, *FEAT)).astype(np.float32)
    pool.pages["pages_k"] = KV.paged_update(
        pool.pages["pages_k"][0], jnp.asarray(vals),
        pool.table_device([slot]), pool.lens_device([slot]),
        PAGE)[None]
    dense[slot, start:start + n_new] = vals[0]
    pool.lens[slot] = start + n_new


def _check_equal(pool, dense):
    view = np.asarray(KV.paged_gather(pool.pages["pages_k"][0],
                                      pool.table_device(), PAGE))
    for b in range(pool.num_slots):
        n = int(pool.lens[b])
        np.testing.assert_array_equal(view[b, :n], dense[b, :n])


# ------------------------------------------------------------ round trips
def test_roundtrip_single_slot():
    rng = np.random.default_rng(0)
    pool = _pool()
    dense = np.zeros((3, MAX_LEN, *FEAT), np.float32)
    _write(pool, 0, 5, rng, dense)      # ragged prefill chunk
    _write(pool, 0, 1, rng, dense)      # decode steps
    _write(pool, 0, 1, rng, dense)
    _check_equal(pool, dense)
    pool.check_no_aliasing()


def test_refill_reuses_freed_pages_wrap_case():
    """Slot 0 outlives several refilled neighbors; the neighbors' reused
    pages must never perturb slot 0's data."""
    rng = np.random.default_rng(1)
    pool = _pool(num_slots=3, num_pages=8)   # tight: forces real reuse
    dense = np.zeros((3, MAX_LEN, *FEAT), np.float32)
    _write(pool, 0, 9, rng, dense)           # long-running resident
    seen_pages = set()
    for cycle in range(4):                   # neighbors churn
        for slot in (1, 2):
            _write(pool, slot, 3 + cycle, rng, dense)
            _check_equal(pool, dense)
            seen_pages.update(
                int(p) for p in pool.page_table[slot] if p >= 0)
            freed = pool.free(slot)
            dense[slot] = 0.0
            assert freed, "neighbor held pages"
            pool.check_no_aliasing()
    _write(pool, 0, 2, rng, dense)           # resident keeps decoding
    _check_equal(pool, dense)
    # churn actually recycled physical pages (the wrap happened)
    assert len(seen_pages) <= pool.num_pages
    assert any(p in seen_pages
               for p in pool.page_table[0] if p >= 0) or len(seen_pages) < 8


def test_random_trace_matches_dense():
    rng = np.random.default_rng(2)
    pool = _pool(num_slots=4)
    dense = np.zeros((4, MAX_LEN, *FEAT), np.float32)
    for _ in range(200):
        slot = int(rng.integers(4))
        room = MAX_LEN - int(pool.lens[slot])
        if rng.random() < 0.2 and pool.lens[slot] > 0:
            pool.free(slot)
            dense[slot] = 0.0
        elif room > 0:
            _write(pool, slot, int(rng.integers(1, min(room, 6) + 1)),
                   rng, dense)
        pool.check_no_aliasing()
    _check_equal(pool, dense)


# ----------------------------------------------------- write-drop guards
def test_write_mask_drops_rows():
    rng = np.random.default_rng(3)
    pool = _pool(num_slots=2)
    dense = np.zeros((2, MAX_LEN, *FEAT), np.float32)
    _write(pool, 0, 4, rng, dense)
    _write(pool, 1, 4, rng, dense)
    pool.alloc(0, 5)                     # room for the unmasked write
    vals = rng.standard_normal((2, 1, *FEAT)).astype(np.float32)
    pool.pages["pages_k"] = KV.paged_update(
        pool.pages["pages_k"][0], jnp.asarray(vals), pool.table_device(),
        pool.lens_device(), PAGE,
        write_mask=jnp.asarray([True, False]))[None]
    dense[0, 4] = vals[0, 0]             # row 1 masked: writes nothing
    pool.lens[0] += 1
    _check_equal(pool, dense)


def test_unmapped_writes_dropped():
    """Writes through -1 table entries (idle slot / chunk padding past
    the allocation) must not corrupt page 0."""
    rng = np.random.default_rng(4)
    pool = _pool(num_slots=2)
    dense = np.zeros((2, MAX_LEN, *FEAT), np.float32)
    _write(pool, 0, 4, rng, dense)       # slot 0 owns page 0
    vals = rng.standard_normal((1, 3, *FEAT)).astype(np.float32)
    # slot 1 has NO pages mapped; its write must vanish, not land in
    # someone else's page
    pool.pages["pages_k"] = KV.paged_update(
        pool.pages["pages_k"][0], jnp.asarray(vals),
        pool.table_device([1]), pool.lens_device([1]), PAGE)[None]
    _check_equal(pool, dense)


# --------------------------------------------------------- allocator law
def test_alloc_oom_raises():
    pool = _pool(num_slots=2, num_pages=2)
    pool.alloc(0, 8)                      # 2 pages: pool exhausted
    with pytest.raises(KV.OutOfPagesError):
        pool.alloc(1, 1)


def test_alloc_beyond_max_len_raises():
    pool = _pool()
    with pytest.raises(ValueError):
        pool.alloc(0, MAX_LEN + 1)


def test_free_returns_pages_and_resets():
    pool = _pool()
    pool.alloc(0, 10)
    held = pool.held(0)
    assert held == KV.pages_for(10, PAGE) == 3
    freed = pool.free(0)
    assert len(freed) == held
    assert pool.held(0) == 0 and int(pool.lens[0]) == 0
    assert pool.free_count == pool.num_pages
    pool.check_no_aliasing()


def test_aliasing_detected():
    pool = _pool()
    pool.alloc(0, 4)
    pool.page_table[1, 0] = pool.page_table[0, 0]     # corrupt: alias
    with pytest.raises(KV.PageAliasError):
        pool.check_no_aliasing()


def test_leak_detected():
    pool = _pool()
    pool.alloc(0, 4)
    pool.page_table[0, 0] = KV.PAGE_FREE              # drop w/o freeing
    with pytest.raises(KV.PageAliasError):
        pool.check_no_aliasing()


def test_leaf_specs_rejects_unsupported_arch():
    cfg = model_zoo.reduced_config(model_zoo.get_config("mamba2-370m"))
    with pytest.raises(NotImplementedError):
        KV.leaf_specs_for(cfg)


def test_max_len_page_divisibility():
    with pytest.raises(ValueError):
        KV.PagedKVCache(num_layers=1, num_slots=1, max_len=10,
                        page_size=4,
                        leaf_specs={"pages_k": (FEAT, jnp.float32)})


# ------------------------------------------------- refcount / COW laws
def test_install_shares_and_free_respects_holders():
    pool = _pool()
    pool.alloc(0, 8)
    pages = [int(p) for p in pool.page_table[0, :2]]
    pool.mark_cached(pages)
    pool.install(1, pages)
    assert all(pool.refcount[p] == 2 for p in pages)
    pool.check_no_aliasing()
    assert pool.free(0) == []            # slot 1 still holds them
    assert pool.free(1) == []            # cached: retained reclaimable
    assert pool.reclaimable_count() == 2
    assert pool.free_count == pool.num_pages - 2
    pool.assert_all_free()               # cached-idle is not a leak


def test_install_guards():
    pool = _pool()
    pool.alloc(0, 4)
    with pytest.raises(KV.PageAliasError):
        pool.install(0, [int(pool.page_table[0, 0])])   # slot not empty
    free_page = pool._free[0]
    with pytest.raises(KV.PageAliasError):
        pool.install(1, [free_page])     # neither live nor cached


def test_fork_copies_and_isolates_writes():
    rng = np.random.default_rng(5)
    pool = _pool(num_slots=2)
    dense = np.zeros((2, MAX_LEN, *FEAT), np.float32)
    _write(pool, 0, 4, rng, dense)       # slot 0 fills page 0
    src = int(pool.page_table[0, 0])
    dst = pool.fork(1, src)
    assert dst != src and pool.refcount[src] == 1 == pool.refcount[dst]
    pool.lens[1] = 2                     # reuse the copied head...
    dense[1, :2] = dense[0, :2]
    _write(pool, 1, 2, rng, dense)       # ...overwrite the tail
    _check_equal(pool, dense)            # slot 0's page untouched
    pool.check_no_aliasing()


def test_fork_under_pressure_evicts_other_cached_pages_not_src():
    pool = _pool(num_slots=2, num_pages=2)
    pool.alloc(0, 8)                     # pool exhausted
    a, b = (int(p) for p in pool.page_table[0, :2])
    pool.mark_cached([a, b])
    pool.free(0)                         # both cached-idle
    evicted = []

    def evictor(n):                      # reclaim any refcount-0 page
        for p in (a, b):
            if len(evicted) < n and pool.refcount[p] == 0:
                evicted.extend(pool.uncache([p]))
        return len(evicted)

    pool.set_evictor(evictor)
    dst = pool.fork(1, b)                # src b pinned across the take
    assert evicted == [a] and dst == a   # the OTHER page was reclaimed
    assert pool.refcount[b] == 0 and b in pool._cached
    pool.check_no_aliasing()


def test_double_free_detected():
    pool = _pool()
    pool.alloc(0, 4)
    p = int(pool.page_table[0, 0])
    pool.free(0)
    with pytest.raises(KV.PageAliasError, match="double free"):
        pool._release(p)


def test_uncache_returns_idle_pages_only():
    pool = _pool()
    pool.alloc(0, 4)
    p = int(pool.page_table[0, 0])
    pool.mark_cached([p])
    assert pool.uncache([p]) == []       # still live: retained
    assert pool.cached_count == 0
    pool.free(0)
    assert pool.free_count == pool.num_pages


def test_assert_all_free_flags_leaks():
    pool = _pool()
    pool.alloc(0, 4)
    with pytest.raises(KV.PageLeakError):
        pool.assert_all_free()
    pool.free(0)
    pool.assert_all_free()


# ------------------------------------------------------------- property
@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 9)),
                    min_size=1, max_size=60),
       num_pages=st.integers(4, 12))
def test_allocator_invariants_property(ops, num_pages):
    """Random alloc/free sequences never alias, never leak, and held
    page counts always match the lengths they cover."""
    pool = _pool(num_slots=4, num_pages=num_pages)
    lens = [0, 0, 0, 0]
    for slot, amount in ops:
        if amount == 0:
            pool.free(slot)
            lens[slot] = 0
        else:
            target = min(lens[slot] + amount, MAX_LEN)
            try:
                pool.alloc(slot, target)
            except KV.OutOfPagesError:
                continue
            lens[slot] = target
            pool.lens[slot] = target
        pool.check_no_aliasing()
        for b in range(4):
            assert pool.held(b) >= KV.pages_for(lens[b], PAGE)
