"""Pallas SSD kernel vs the pure-jnp oracle (models/ssm.ssd_chunked),
interpret mode, shape/dtype sweep per the kernel-validation protocol."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ssd as K
from repro.models import ssm


def _mk(bh, t, p, n, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((bh, t, p)), dtype)
    a = jnp.asarray(-np.abs(rng.standard_normal((bh, t))) * 0.1, dtype)
    b = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.3, dtype)
    c = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.3, dtype)
    return x, a, b, c


def _oracle(x, a, b, c, chunk):
    # oracle wants [B, T, H, P] with groups; use B=BH, H=1, G=1
    bh, t, p = x.shape
    y, _ = ssm.ssd_chunked(
        x.reshape(bh, t, 1, p).swapaxes(0, 0),
        a.reshape(bh, t, 1),
        b.reshape(bh, t, 1, -1),
        c.reshape(bh, t, 1, -1),
        chunk=chunk)
    return y.reshape(bh, t, p)


@pytest.mark.parametrize("bh,t,p,n,chunk", [
    (2, 64, 16, 32, 16),
    (3, 128, 32, 16, 32),
    (1, 256, 64, 128, 128),      # mamba2-370m head geometry
    (4, 32, 8, 8, 8),
])
def test_ssd_kernel_matches_oracle(bh, t, p, n, chunk):
    x, a, b, c = _mk(bh, t, p, n)
    y = K.ssd(x, a, b, c, chunk=chunk, interpret=True)
    ref = _oracle(x, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    x, a, b, c = _mk(2, 64, 16, 16, dtype=np.float32, seed=1)
    x, a, b, c = (z.astype(dtype) for z in (x, a, b, c))
    y = K.ssd(x, a, b, c, chunk=32, interpret=True)
    assert y.dtype == dtype
    ref = _oracle(x.astype(jnp.float32), a.astype(jnp.float32),
                  b.astype(jnp.float32), c.astype(jnp.float32), 32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)


def test_state_carries_across_chunks():
    """Long-range dependence: token 0 must influence the last chunk's
    output (the state scratch carry — the kernel's Z-discipline)."""
    x, a, b, c = _mk(1, 128, 8, 8, seed=2)
    y1 = K.ssd(x, a, b, c, chunk=32, interpret=True)
    x2 = x.at[0, 0].add(10.0)
    y2 = K.ssd(x2, a, b, c, chunk=32, interpret=True)
    last = np.abs(np.asarray(y1[0, -32:]) - np.asarray(y2[0, -32:]))
    assert last.max() > 1e-6, "state did not carry across chunks"


def test_vmem_budget():
    assert K.vmem_bytes(128, 64, 128) < 16 * 2 ** 20
