"""Cross-request prefix cache gates: trie laws (longest match on page
boundaries, the last-token-recomputed cap, COW divergence detection,
insert idempotence), LRU eviction laws (refcount-0 only, cascade,
pinned pages survive), the refcount/COW page laws they ride on, and the
scheduler integration — duplicate-prefix schedules through a stub
engine must keep every existing invariant plus zero leaked pages."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.runtime import kv_cache as KV
from repro.runtime.batching import ContinuousBatchingScheduler
from repro.runtime.prefix_cache import PrefixCache

PAGE = 4
MAX_LEN = 16
FEAT = (2,)


def _pool(num_slots=3, num_pages=None):
    return KV.PagedKVCache(
        num_layers=1, num_slots=num_slots, max_len=MAX_LEN,
        page_size=PAGE, leaf_specs={"pages_k": (FEAT, jnp.float32)},
        num_pages=num_pages)


def _prompt(*tokens):
    return np.asarray(tokens, np.int32)


def _complete(pool, cache, slot, tokens):
    """Simulate a finished prefill: alloc + set length, then index the
    prompt (what _prefill_step does on its final chunk)."""
    pool.alloc(slot, len(tokens))
    pool.lens[slot] = len(tokens)
    return cache.insert(slot, tokens)


# ------------------------------------------------------------- trie laws
def test_lookup_cold_is_a_miss():
    cache = PrefixCache(_pool())
    hit = cache.lookup(_prompt(1, 2, 3, 4, 5))
    assert hit.tokens == 0 and not hit.nodes and hit.fork_node is None


def test_identical_prompt_caps_at_last_token():
    """A verbatim re-ask still recomputes its final position — the
    logits there seed generation — so the second page is reused by COW
    fork, never shared outright."""
    pool, cache = _pool(), None
    cache = PrefixCache(pool)
    toks = _prompt(1, 2, 3, 4, 5, 6, 7, 8)
    _complete(pool, cache, 0, toks)
    hit = cache.lookup(toks)
    assert len(hit.nodes) == 1                 # page 0 shared whole
    assert hit.fork_node is not None           # page 1: COW, head only
    assert hit.fork_reuse == 3
    assert hit.tokens == 7 == len(toks) - 1


def test_longest_match_walks_page_boundaries():
    pool = _pool()
    cache = PrefixCache(pool)
    _complete(pool, cache, 0, _prompt(*range(1, 13)))       # 3 pages
    # shares 2 full pages, diverges at position 8
    hit = cache.lookup(_prompt(1, 2, 3, 4, 5, 6, 7, 8, 99, 98, 97))
    assert len(hit.nodes) == 2 and hit.fork_node is None
    assert hit.tokens == 8


def test_mid_page_divergence_is_a_cow_candidate():
    pool = _pool()
    cache = PrefixCache(pool)
    _complete(pool, cache, 0, _prompt(*range(1, 13)))
    # shares page 0 + two tokens of page 1
    hit = cache.lookup(_prompt(1, 2, 3, 4, 5, 6, 99, 98, 97, 96))
    assert len(hit.nodes) == 1
    assert hit.fork_node is not None and hit.fork_reuse == 2
    assert hit.tokens == 6


def test_sibling_runs_branch_like_a_radix_tree():
    pool = _pool()
    cache = PrefixCache(pool)
    _complete(pool, cache, 0, _prompt(1, 2, 3, 4, 10, 11, 12, 13))
    _complete(pool, cache, 1, _prompt(1, 2, 3, 4, 20, 21, 22, 23))
    assert cache.num_pages == 3                # shared head page once
    for tail, want in (((10, 11, 12, 13), 7), ((20, 21, 22, 23), 7)):
        hit = cache.lookup(_prompt(1, 2, 3, 4, *tail))
        assert hit.tokens == want              # own branch found
    # the deepest-sharing sibling wins the fork candidacy
    hit = cache.lookup(_prompt(1, 2, 3, 4, 20, 21, 99, 98))
    assert hit.fork_reuse == 2 and hit.tokens == 6


def test_insert_is_idempotent_and_keeps_first_page():
    pool = _pool()
    cache = PrefixCache(pool)
    toks = _prompt(1, 2, 3, 4, 5, 6, 7, 8)
    assert _complete(pool, cache, 0, toks) == 2
    first = [n.page for n in cache._walk()]
    # racing cold duplicate finishes in another slot: nothing re-indexed
    assert _complete(pool, cache, 1, toks) == 0
    assert sorted(n.page for n in cache._walk()) == sorted(first)
    assert cache.stats.inserted_pages == 2


def test_partial_final_page_never_indexed():
    pool = _pool()
    cache = PrefixCache(pool)
    assert _complete(pool, cache, 0, _prompt(1, 2, 3, 4, 5, 6)) == 1
    assert cache.num_pages == 1                # the 2-token tail stays private


# ----------------------------------------------------------- admit laws
def test_admit_shares_pages_and_forks_divergence():
    pool = _pool()
    cache = PrefixCache(pool)
    toks = _prompt(*range(1, 13))
    _complete(pool, cache, 0, toks)
    pool.free(0)                               # pages survive cached
    covered = cache.admit(1, _prompt(1, 2, 3, 4, 5, 6, 99, 98, 97, 96))
    assert covered == 6
    shared = int(pool.page_table[1, 0])
    forked = int(pool.page_table[1, 1])
    trie_pages = [n.page for n in cache._walk()]
    assert shared in trie_pages                # head page shared
    assert forked not in trie_pages            # fork page private
    assert pool.refcount[shared] == 1 and pool.refcount[forked] == 1
    assert cache.stats.cow_forks == 1 and cache.stats.hit_tokens == 6
    pool.check_no_aliasing()


def test_fork_copies_page_contents():
    pool = _pool()
    cache = PrefixCache(pool)
    rng = np.random.default_rng(0)
    vals = rng.standard_normal((1, 8, *FEAT)).astype(np.float32)
    pool.alloc(0, 8)
    pool.pages["pages_k"] = KV.paged_update(
        pool.pages["pages_k"][0], jnp.asarray(vals),
        pool.table_device([0]), pool.lens_device([0]), PAGE)[None]
    pool.lens[0] = 8
    cache.insert(0, _prompt(1, 2, 3, 4, 5, 6, 7, 8))
    pool.free(0)
    cache.admit(1, _prompt(1, 2, 3, 4, 5, 6, 7, 99, 98))
    view = np.asarray(KV.paged_gather(
        pool.pages["pages_k"][0], pool.table_device([1]), PAGE))
    # shared page verbatim + the forked page's reused head
    np.testing.assert_array_equal(view[0, :7], vals[0, :7])


def test_admit_cold_prompt_returns_zero():
    pool = _pool()
    cache = PrefixCache(pool)
    assert cache.admit(0, _prompt(5, 6, 7, 8, 9)) == 0
    assert pool.held(0) == 0 and cache.stats.misses == 1


# --------------------------------------------------------- eviction laws
def test_eviction_is_lru_over_refcount0_leaves():
    pool = _pool(num_slots=2, num_pages=4)
    cache = PrefixCache(pool)
    a, b = _prompt(1, 2, 3, 4, 9), _prompt(5, 6, 7, 8, 9)
    for toks in (a, b):
        _complete(pool, cache, 0, toks)
        pool.free(0)
    cache.admit(0, a)                          # touch a: b is now LRU
    pool.free(0)
    page_b = cache.lookup(b).nodes[0].page if cache.lookup(b).nodes \
        else None
    pool.alloc(1, 12)                          # 3 pages: needs 1 eviction
    assert cache.stats.evicted_pages == 1
    assert cache.lookup(b).tokens == 0         # b evicted...
    assert cache.lookup(a).tokens == 4         # ...a survived
    assert page_b is not None
    pool.check_no_aliasing()


def test_live_shared_pages_never_evicted():
    pool = _pool(num_slots=2, num_pages=2)
    cache = PrefixCache(pool)
    toks = _prompt(1, 2, 3, 4, 9)
    _complete(pool, cache, 0, toks)            # 2 pages: 1 cached + tail
    pool.free(0)
    cache.admit(0, toks)                       # cached page now live
    with pytest.raises(KV.OutOfPagesError):
        pool.alloc(1, 8)                       # only a live page remains
    assert cache.stats.evicted_pages == 0
    assert cache.lookup(toks).tokens == 4      # index intact
    pool.check_no_aliasing()


def test_eviction_cascades_through_emptied_parents():
    pool = _pool(num_slots=2, num_pages=4)
    cache = PrefixCache(pool)
    _complete(pool, cache, 0, _prompt(*range(1, 13)))   # 3-page chain
    pool.free(0)
    assert cache.num_pages == 3
    pool.alloc(1, 12)                          # demand the whole pool
    assert cache.stats.evicted_pages >= 2      # leaf, then its parent
    pool.check_no_aliasing()


def test_clear_returns_idle_pages():
    pool = _pool()
    cache = PrefixCache(pool)
    _complete(pool, cache, 0, _prompt(*range(1, 9)))
    pool.free(0)
    assert pool.free_count < pool.num_pages
    assert cache.clear() == 2
    assert cache.num_pages == 0
    pool.assert_all_free()


def test_reclaimable_count_is_exact():
    pool = _pool()
    cache = PrefixCache(pool)
    toks = _prompt(*range(1, 9))
    _complete(pool, cache, 0, toks)
    assert pool.reclaimable_count() == 0       # cached pages still live
    pool.free(0)
    assert pool.reclaimable_count() == 2
    cache.admit(1, toks)                       # hit pins the shared page
    # page0 is live (shared); page1 was only COPIED by the COW fork, so
    # it returns to refcount 0 and stays reclaimable
    assert pool.reclaimable_count() == 1
    pool.free(1)
    assert pool.reclaimable_count() == 2
    assert pool.reclaimable_count(exclude=[
        n.page for n in cache._walk()]) == 0


# ------------------------------------------------------------- property
@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2),
                              st.integers(0, 5)),
                    min_size=1, max_size=50),
       num_pages=st.integers(3, 10),
       seed=st.integers(0, 99))
def test_cache_pool_interleaving_property(ops, num_pages, seed):
    """Arbitrary admit/complete/free/pressure interleavings never alias,
    never leak, never double-free — and teardown always audits clean."""
    rng = np.random.default_rng(seed)
    pool = _pool(num_slots=3, num_pages=num_pages)
    cache = PrefixCache(pool)
    prompts = [rng.integers(1, 5, rng.integers(2, MAX_LEN - 3))
               .astype(np.int32) for _ in range(4)]
    lens = [0, 0, 0]
    for op, slot, arg in ops:
        toks = prompts[arg % len(prompts)]
        try:
            if op == 0 and lens[slot] == 0:        # admit w/ prefix
                lens[slot] = max(cache.admit(slot, toks), 1)
                pool.alloc(slot, min(len(toks), MAX_LEN))
                pool.lens[slot] = lens[slot]
            elif op == 1 and lens[slot] > 0:       # complete + index
                pool.alloc(slot, len(toks))
                pool.lens[slot] = len(toks)
                cache.insert(slot, toks)
            elif op == 2:                          # finish
                pool.free(slot)
                lens[slot] = 0
            elif op == 3 and lens[slot] > 0:       # decode growth
                pool.alloc(slot, min(int(pool.lens[slot]) + arg + 1,
                                     MAX_LEN))
        except KV.OutOfPagesError:
            pool.free(slot)                        # abort the request
            lens[slot] = 0
        pool.check_no_aliasing()
        assert pool.reclaimable_count() == sum(
            1 for n in cache._walk() if pool.refcount[n.page] == 0)
    for slot in range(3):
        pool.free(slot)
    cache.clear()
    pool.assert_all_free()


# ------------------------------------------- scheduler integration (stub)
class _FakeEngine:
    """Duck-typed engine (test_serving.py's pattern): scheduling logic
    only, so duplicate-prefix schedules run cheaply."""

    def __init__(self, cfg, max_len):
        self.cfg = cfg
        self.max_len = max_len

    def prefill_chunk(self, pages, pt, lens, tokens, logit_index, *,
                      page_size):
        return jnp.zeros((), jnp.int32), pages

    def decode_step(self, pages, pt, lens, mask, last, *, page_size):
        return last, pages


def _fake_cfg():
    from repro.models import model_zoo
    return model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))


def test_scheduler_duplicate_prompts_hit_and_audit_clean():
    cfg = _fake_cfg()
    rng = np.random.default_rng(3)
    shared = rng.integers(1, cfg.vocab_size, 18).astype(np.int32)
    reqs = [np.concatenate([shared,
                            rng.integers(1, cfg.vocab_size, 4)
                            .astype(np.int32)]) for _ in range(6)]
    sched = ContinuousBatchingScheduler(
        _FakeEngine(cfg, 48), batch_slots=2, prefill_chunk=8,
        page_size=8, check_invariants=True, prefix_cache=True)
    outs, stats = sched.run(reqs, [3] * 6)
    assert [len(o) for o in outs] == [3] * 6
    assert stats.prefix is not None and stats.prefix.hits >= 4
    assert stats.prefix.hit_tokens > 0
    # computed prefill = total prompt tokens minus what the cache covered
    assert stats.prefill_tokens == sum(len(r) for r in reqs) \
        - stats.prefix.hit_tokens
    hits = [ev for ev in sched.trace if ev[0] == "prefix_hit"]
    assert len(hits) == stats.prefix.hits
    sched.kv.check_no_aliasing()               # run() already audited


def test_scheduler_cache_survives_runs_and_pressure():
    """The index outlives run(): a second run over the same prompts hits
    warm, and a page-pressured run must evict instead of deadlocking."""
    cfg = _fake_cfg()
    rng = np.random.default_rng(4)
    reqs = [rng.integers(1, cfg.vocab_size, 20).astype(np.int32)
            for _ in range(3)]
    sched = ContinuousBatchingScheduler(
        _FakeEngine(cfg, 48), batch_slots=1, prefill_chunk=8,
        page_size=8, check_invariants=True, prefix_cache=True)
    sched.run(reqs, [2] * 3)
    h0 = sched.stats.prefix.hits
    sched.run(reqs, [2] * 3)                   # same prompts, warm index
    assert sched.stats.prefix.hits >= h0 + 3
    tight = ContinuousBatchingScheduler(
        _FakeEngine(cfg, 48), batch_slots=2, prefill_chunk=8,
        page_size=8, num_pages=4, check_invariants=True,
        prefix_cache=True)
    outs, stats = tight.run(
        [rng.integers(1, cfg.vocab_size, 14).astype(np.int32)
         for _ in range(5)], [2] * 5)
    assert [len(o) for o in outs] == [2] * 5
    assert stats.prefix.evicted_pages > 0
