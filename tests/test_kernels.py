"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
bit-exactness vs the blocked oracle (the paper's 0e+00 discipline), and
hypothesis property tests on the GEMM invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.core import bitexact
from repro.kernels import ops, ref
from repro.kernels.panel_gemm import panel_gemm, vmem_bytes, VMEM_BUDGET

RNG = np.random.default_rng(42)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------- panel gemm
@pytest.mark.parametrize("m,n,k", [
    (128, 256, 256), (128, 512, 128), (256, 128, 384),
    (128, 2048 // 4, 2048 // 4),   # scaled QKV class
    (128, 8192 // 16, 2048 // 8),  # scaled FFN1 (N > K)
    (128, 2048 // 8, 8192 // 16),  # scaled FFN2 (K > N)
])
def test_panel_gemm_vs_blocked_oracle_bitexact(m, n, k):
    x, w = _rand((m, k)), _rand((k, n))
    bk = min(128, k)
    y = panel_gemm(x, w, block_m=128, block_n=128, block_k=bk,
                   interpret=True)
    bitexact.assert_bit_identical(
        np.asarray(y), np.asarray(ref.gemm_blocked(x, w, bk)))


@pytest.mark.parametrize("m,n,k", [(64, 96, 200), (128, 130, 256),
                                   (1, 300, 77), (129, 128, 128)])
def test_panel_gemm_unaligned_shapes(m, n, k):
    x, w = _rand((m, k)), _rand((k, n))
    y = ops.gemm(x, w, interpret=True)
    np.testing.assert_allclose(y, ref.gemm_xla(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_panel_gemm_dtypes(dtype):
    x = _rand((128, 256)).astype(dtype)
    w = _rand((256, 128)).astype(dtype)
    y = panel_gemm(x, w, block_m=128, block_n=128, block_k=128,
                   interpret=True)
    expect = ref.gemm_blocked(x, w, 128)
    assert y.dtype == dtype
    if dtype == jnp.float32:
        bitexact.assert_bit_identical(np.asarray(y), np.asarray(expect))
    else:
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_panel_gemm_kcarry_no_leak_across_tiles():
    """The skip-Z discipline: two output tiles sharing the accumulator
    scratch must not leak partial sums (grid > 1 in both i and j)."""
    x, w = _rand((256, 512)), _rand((512, 256))
    y = panel_gemm(x, w, block_m=128, block_n=128, block_k=128,
                   interpret=True)
    bitexact.assert_bit_identical(
        np.asarray(y), np.asarray(ref.gemm_blocked(x, w, 128)))


def test_vmem_model_deployed_blocks_fit():
    from repro.kernels.panel_gemm import (DEFAULT_BLOCK_K, DEFAULT_BLOCK_M,
                                          DEFAULT_BLOCK_N)
    assert vmem_bytes(DEFAULT_BLOCK_M, DEFAULT_BLOCK_N,
                      DEFAULT_BLOCK_K) <= VMEM_BUDGET


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64), n=st.integers(1, 64), k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_matches_xla_property(m, n, k, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(r.standard_normal((k, n)).astype(np.float32))
    y = ops.gemm(x, w, interpret=True)
    np.testing.assert_allclose(y, ref.gemm_xla(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gemm_linearity_property(seed):
    """GEMM invariant: (a x1 + x2) W == a (x1 W) + x2 W (fp32, loose tol)."""
    r = np.random.default_rng(seed)
    x1 = jnp.asarray(r.standard_normal((32, 64)).astype(np.float32))
    x2 = jnp.asarray(r.standard_normal((32, 64)).astype(np.float32))
    w = jnp.asarray(r.standard_normal((64, 32)).astype(np.float32))
    lhs = ops.gemm(2.0 * x1 + x2, w, interpret=True)
    rhs = 2.0 * ops.gemm(x1, w, interpret=True) + ops.gemm(
        x2, w, interpret=True)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("s,t,h,hkv,d", [
    (128, 128, 4, 4, 64), (256, 256, 4, 2, 64), (64, 192, 8, 2, 32),
    (100, 100, 2, 1, 80),
])
def test_flash_attention_vs_ref(s, t, h, hkv, d):
    q = _rand((2, s, h, d))
    k = _rand((2, t, hkv, d))
    v = _rand((2, t, hkv, d))
    o = ops.mha(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(o, ref.attention(q, k, v, causal=True),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(None, 30.0), (64, None),
                                            (64, 50.0), (17, None)])
def test_flash_attention_window_softcap(window, softcap):
    q, k, v = _rand((1, 256, 4, 64)), _rand((1, 256, 2, 64)), _rand(
        (1, 256, 2, 64))
    o = ops.mha(q, k, v, causal=True, window=window, softcap=softcap,
                interpret=True)
    np.testing.assert_allclose(
        o, ref.attention(q, k, v, causal=True, window=window,
                         softcap=softcap), rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_cache_alignment():
    """Sq < Skv (decode/cache case): positions must align to cache end."""
    q, k, v = _rand((2, 1, 4, 64)), _rand((2, 300, 4, 64)), _rand(
        (2, 300, 4, 64))
    o = ops.mha(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(o, ref.attention(q, k, v, causal=True),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    q = _rand((1, 128, 2, 64)).astype(dtype)
    k = _rand((1, 128, 2, 64)).astype(dtype)
    v = _rand((1, 128, 2, 64)).astype(dtype)
    o = ops.mha(q, k, v, causal=True, interpret=True)
    o_ref = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)
