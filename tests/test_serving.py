"""Continuous-batching serving gates.

The serving analogue of the paper's bit-exactness protocol: for a fixed
request set, the continuous-batching ``serve`` must return
token-for-token identical outputs to per-request greedy ``generate``,
across batch_slots in {1, 2, 4} and mixed prompt lengths — plus the
scheduler invariants (slot exclusivity, exactly-once completion, FIFO
admission, no freed-page aliasing) and the plans-stay-hot property
(``plan_cache_info().misses`` flat after the first refill cycle).
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro import gemm
from repro.models import model_zoo
from repro.runtime.batching import ContinuousBatchingScheduler
from repro.runtime.serve_loop import Engine

MAX_LEN = 48
PAGE = 8
CHUNK = 8
# mixed prompt lengths: < chunk, == chunk, ragged tails, near max
LENS = [5, 17, 8, 23, 3, 12]
MNS = [6, 3, 8, 4, 5, 7]


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in lens]


def _refs(eng, reqs, mns):
    return [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, mns)]


@pytest.fixture(scope="module")
def stablelm():
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    return cfg, Engine(cfg, params, max_len=MAX_LEN, packed=False)


@pytest.fixture(scope="module")
def stablelm_packed():
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    params = model_zoo.build(cfg)
    return cfg, Engine(cfg, params, max_len=MAX_LEN, packed=True)


# ----------------------------------------------------------- parity gate
@pytest.mark.parametrize("megastep", [1, 4])
@pytest.mark.parametrize("batch_slots", [1, 2, 4])
def test_parity_vs_per_request_generate(stablelm, batch_slots, megastep):
    """Bit-identical to per-request generate at every megastep depth:
    a D-deep megastep drain runs the SAME jitted tick D times device-
    side, so fusing the loop must not move a single bit."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS)
    refs = _refs(eng, reqs, MNS)
    outs, stats = eng.serve(reqs, batch_slots=batch_slots,
                            max_new_tokens=MNS, prefill_chunk=CHUNK,
                            page_size=PAGE, check_invariants=True,
                            megastep_depth=megastep)
    for i, (o, r) in enumerate(zip(outs, refs)):
        np.testing.assert_array_equal(
            o, r, err_msg=f"request {i} diverged at batch_slots="
                          f"{batch_slots}, megastep={megastep}")
    assert stats.prefill_tokens == sum(LENS)
    assert stats.decode_tokens == sum(MNS)
    assert stats.decode_ticks == len(stats.decode_tick_ms)
    if megastep > 1:
        # the dispatch collapse actually happened
        assert stats.decode_dispatches < stats.decode_ticks
    else:
        assert stats.decode_dispatches == stats.decode_ticks


@pytest.mark.parametrize("megastep", [1, 4])
def test_parity_packed_engine(stablelm_packed, megastep):
    """The packed (plan/execute) path — decode plans through the decode
    lane — must satisfy the same gate at every megastep depth."""
    cfg, eng = stablelm_packed
    reqs = _requests(cfg, LENS[:4])
    refs = _refs(eng, reqs, MNS[:4])
    outs, _ = eng.serve(reqs, batch_slots=2, max_new_tokens=MNS[:4],
                        prefill_chunk=CHUNK, page_size=PAGE,
                        megastep_depth=megastep)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_parity_softcap_window_arch():
    """gemma2: logit softcap + alternating local/global windows."""
    cfg = model_zoo.reduced_config(model_zoo.get_config("gemma2-9b"))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=MAX_LEN, packed=False)
    reqs = _requests(cfg, [5, 20, 11], seed=1)
    mns = [4, 6, 3]
    refs = _refs(eng, reqs, mns)
    outs, _ = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                        prefill_chunk=CHUNK, page_size=PAGE)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_parity_under_page_pressure(stablelm):
    """A pool smaller than the dense equivalent forces admission to wait
    for freed pages; outputs must not change."""
    cfg, eng = stablelm
    lens, mns = [20, 20, 20, 20], [8, 8, 8, 8]
    reqs = _requests(cfg, lens, seed=2)
    refs = _refs(eng, reqs, mns)
    # 4 slots x 6 pages dense-equivalent = 24; 9 admits at most two
    outs, stats = eng.serve(reqs, batch_slots=4, max_new_tokens=mns,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            num_pages=9, check_invariants=True,
                            sync_per_step=True)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    assert max(r.queue_wait_s for r in stats.requests) > 0


# ------------------------------------------------------ plans stay hot
def test_plan_misses_flat_after_first_refill_cycle(stablelm_packed):
    cfg, eng = stablelm_packed
    reqs = _requests(cfg, LENS, seed=3)
    eng.serve(reqs, batch_slots=2, max_new_tokens=MNS,
              prefill_chunk=CHUNK, page_size=PAGE)
    misses = gemm.plan_cache_info().misses
    # fresh mixed lengths, several refill cycles — same static shapes
    reqs2 = _requests(cfg, [7, 19, 2, 11, 23, 4], seed=4)
    eng.serve(reqs2, batch_slots=2, max_new_tokens=[3, 5, 2, 6, 4, 3],
              prefill_chunk=CHUNK, page_size=PAGE)
    assert gemm.plan_cache_info().misses == misses, \
        "steady-state serving replanned a GEMM"


def test_bucket_m_plan_key_stability():
    """Ragged chunk row counts inside one bucket share one plan key."""
    assert [gemm.bucket_m(m) for m in (1, 8, 9, 16, 33, 64, 65, 129)] \
        == [8, 8, 16, 16, 64, 64, 128, 256]
    with pytest.raises(ValueError):
        gemm.bucket_m(0)
    gemm.plan_cache_clear()
    for m in (17, 20, 31, 32):           # all bucket to 32
        gemm.plan(gemm.bucket_m(m), 64, 256)
    assert gemm.plan_cache_info().misses == 1


# ------------------------------------------------- scheduler invariants
def _audit_trace(trace, n_requests):
    """Replay the scheduler's event log against the serving invariants."""
    active = {}                          # slot -> rid
    admitted, finished = [], []
    for ev in trace:
        if ev[0] == "admit":
            rid, slot = ev[1], ev[2]
            assert slot not in active, \
                f"slot {slot} admitted {rid} while serving {active[slot]}"
            active[slot] = rid
            admitted.append(rid)
        elif ev[0] == "decode":
            assert all(r in active.values() for r in ev[1]), \
                "decoded a request not assigned to any slot"
        elif ev[0] == "finish":
            rid, slot = ev[1], ev[2]
            assert active.get(slot) == rid
            del active[slot]
            finished.append(rid)
    assert not active, f"requests never finished: {active}"
    assert admitted == sorted(admitted), "FIFO admission order broken"
    assert sorted(finished) == list(range(n_requests)), \
        "each request must complete exactly once"


class FakeEngine:
    """Duck-typed engine: scheduling logic only, no tracing — lets the
    invariant property run thousands of schedules cheaply."""

    def __init__(self, cfg, max_len):
        self.cfg = cfg
        self.max_len = max_len

    def prefill_chunk(self, pages, pt, lens, tokens, logit_index, *,
                      page_size):
        return jnp.zeros((), jnp.int32), pages

    def decode_step(self, pages, pt, lens, mask, last, *, page_size):
        return last, pages


def _fake_cfg():
    return model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))


def _run_schedule(cfg, lens, mns, *, batch_slots, num_pages=None):
    sched = ContinuousBatchingScheduler(
        FakeEngine(cfg, MAX_LEN), batch_slots=batch_slots,
        prefill_chunk=CHUNK, page_size=PAGE, num_pages=num_pages,
        check_invariants=True)
    reqs = _requests(cfg, lens, seed=7)
    outs, stats = sched.run(reqs, mns)
    _audit_trace(sched.trace, len(lens))
    assert [len(o) for o in outs] == list(mns)
    assert stats.prefill_tokens == sum(lens)
    assert stats.decode_tokens == sum(mns)
    sched.kv.check_no_aliasing()
    assert sched.kv.free_count == sched.kv.num_pages, "pages leaked"
    return sched


def test_scheduler_invariants_deterministic():
    cfg = _fake_cfg()
    for slots in (1, 2, 4):
        _run_schedule(cfg, LENS, MNS, batch_slots=slots)
    # pressure: at most one live request's worth of pages
    _run_schedule(cfg, [20, 20, 20], [8, 8, 8], batch_slots=3,
                  num_pages=5)


def test_real_engine_trace_invariants(stablelm):
    cfg, eng = stablelm
    sched = ContinuousBatchingScheduler(
        eng, batch_slots=2, prefill_chunk=CHUNK, page_size=PAGE,
        check_invariants=True)
    sched.run(_requests(cfg, LENS), MNS)
    _audit_trace(sched.trace, len(LENS))


@settings(max_examples=40, deadline=None)
@given(lens=st.lists(st.integers(1, 30), min_size=1, max_size=10),
       seed=st.integers(0, 2 ** 16),
       batch_slots=st.integers(1, 5),
       tight=st.booleans())
def test_scheduler_invariants_property(lens, seed, batch_slots, tight):
    """No slot serves two requests at once, every request completes
    exactly once, FIFO admission holds, freed pages never alias — for
    arbitrary request mixes, pool widths, and page pressure."""
    rng = np.random.default_rng(seed)
    mns = [int(rng.integers(1, min(12, MAX_LEN - l + 1) + 1))
           for l in lens]
    need_max = max(-(-(l + m - 1) // PAGE) for l, m in zip(lens, mns))
    num_pages = None
    if tight:      # smallest pool that can still admit the largest req
        num_pages = max(need_max, 2)
    _run_schedule(_fake_cfg(), lens, mns, batch_slots=batch_slots,
                  num_pages=num_pages)


# --------------------------------------------------- stats + guard rails
def test_genstats_generate_counts_emitted_tokens(stablelm):
    """GenStats bug fix: generate emits max_new tokens per row and the
    stats must say so (not b * (max_new - 1))."""
    cfg, eng = stablelm
    prompts = jnp.asarray(_requests(cfg, [6, 6, 6])[0])[None]
    prompts = jnp.tile(prompts, (3, 1))
    _, stats = eng.generate(prompts, 5)
    assert stats.decode_tokens == 3 * 5
    assert stats.prefill_tokens == 3 * 6


def test_serve_chunked_counts_only_live_nonpad(stablelm):
    """Dead slots (len(chunk) < batch_slots), prompt padding, and
    over-generation past a request's own budget count nothing."""
    cfg, eng = stablelm
    lens, mns = [5, 9, 3], [4, 2, 6]       # 3 requests, 2 slots
    reqs = _requests(cfg, lens, seed=5)
    outs, stats = eng.serve_chunked(reqs, batch_slots=2, prompt_len=16,
                                    max_new_tokens=mns)
    assert stats.prefill_tokens == sum(lens)       # not 2 chunks * 2 * 16
    assert stats.decode_tokens == sum(mns)         # not sum of chunk maxes
    assert [len(o) for o in outs] == mns


def test_serve_rejects_oversized_request(stablelm):
    cfg, eng = stablelm
    with pytest.raises(ValueError):
        eng.serve(_requests(cfg, [MAX_LEN]), batch_slots=2,
                  max_new_tokens=8, page_size=PAGE)
    with pytest.raises(ValueError):
        eng.serve([np.zeros((0,), np.int32)], batch_slots=2,
                  max_new_tokens=2, page_size=PAGE)


def test_serve_stats_latency_fields(stablelm):
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS[:3], seed=6)
    _, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=3,
                         prefill_chunk=CHUNK, page_size=PAGE,
                         sync_per_step=True)
    assert len(stats.requests) == 3
    for r in stats.requests:
        assert r.ttft_s >= r.queue_wait_s >= 0
        assert r.total_s >= r.ttft_s
        assert r.decode_tps > 0
    assert stats.percentile("ttft_s", 95) >= stats.percentile("ttft_s", 5)
    assert stats.wall_s > 0 and stats.total_tps > 0


def test_serve_stats_phase_breakdown(stablelm):
    """Per-phase tick latency + host-sync accounting (decode lane
    observability satellite)."""
    cfg, eng = stablelm
    reqs = _requests(cfg, LENS[:3], seed=8)
    _, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=5,
                         prefill_chunk=CHUNK, page_size=PAGE,
                         sync_per_step=True, megastep_depth=2)
    assert stats.megastep_depth == 2
    assert len(stats.prefill_tick_ms) > 0
    assert stats.decode_ticks > 0
    assert stats.phase_percentile("decode", 99) >= \
        stats.phase_percentile("decode", 50) > 0
    assert stats.phase_percentile("prefill", 50) > 0
    # sync_per_step: one blocking sync per device dispatch + the final
    # materialize
    assert stats.host_syncs == (len(stats.prefill_tick_ms)
                                + stats.decode_dispatches + 1)
    # async run: only the end-of-run materialize blocks
    _, astats = eng.serve(_requests(cfg, LENS[:2], seed=9),
                          batch_slots=2, max_new_tokens=3,
                          prefill_chunk=CHUNK, page_size=PAGE)
    assert astats.host_syncs == 1


def test_megastep_under_page_pressure(stablelm):
    """Deep megasteps pre-allocate D tokens of pages per drain; the
    reservation-based admission must stay deadlock-free and parity must
    hold when the pool is tight."""
    cfg, eng = stablelm
    lens, mns = [20, 20, 20, 20], [8, 8, 8, 8]
    reqs = _requests(cfg, lens, seed=2)
    refs = _refs(eng, reqs, mns)
    outs, stats = eng.serve(reqs, batch_slots=4, max_new_tokens=mns,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            num_pages=9, check_invariants=True,
                            megastep_depth=4)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_megastep_never_overshoots_max_new(stablelm):
    """The realized drain depth caps at the smallest remaining budget:
    no slot generates past its max_new, so the trace finish events and
    emitted counts are exact at any depth."""
    cfg, eng = stablelm
    reqs = _requests(cfg, [5, 7], seed=10)
    sched = ContinuousBatchingScheduler(
        eng, batch_slots=2, prefill_chunk=CHUNK, page_size=PAGE,
        check_invariants=True, megastep_depth=8)
    outs, stats = sched.run(reqs, [3, 9])   # one budget far below D
    _audit_trace(sched.trace, 2)
    assert [len(o) for o in outs] == [3, 9]
    assert stats.decode_tokens == 12


def test_megastep_requires_capable_engine():
    cfg = _fake_cfg()
    with pytest.raises(ValueError, match="decode_megastep"):
        ContinuousBatchingScheduler(
            FakeEngine(cfg, MAX_LEN), batch_slots=2,
            prefill_chunk=CHUNK, page_size=PAGE, megastep_depth=4)
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(
            FakeEngine(cfg, MAX_LEN), batch_slots=2,
            prefill_chunk=CHUNK, page_size=PAGE, megastep_depth=0)


# ---------------------------------------------------- prefix cache gates
def _shared_prefix_reqs(cfg, groups, per_group, prefix_len, tail_lens,
                        seed=12):
    """``groups`` distinct shared preambles, ``per_group`` requests each
    (tails unique), interleaved by group so warm hits happen mid-run."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(1, cfg.vocab_size, prefix_len)
                .astype(np.int32) for _ in range(groups)]
    reqs = []
    for i in range(per_group):
        for p in prefixes:
            t = tail_lens[(i * groups) % len(tail_lens)] + len(reqs) % 3
            reqs.append(np.concatenate(
                [p, rng.integers(1, cfg.vocab_size, t).astype(np.int32)]))
    return reqs


def test_prefix_cache_parity_cold_warm_cow(stablelm):
    """The tentpole gate: serve with the cache on stays token-identical
    to per-request generate through cold admissions, warm full-page
    hits, AND mid-page COW forks (prefix_len 18 = 2 full pages + 2
    tokens into the divergence page at PAGE=8)."""
    cfg, eng = stablelm
    reqs = _shared_prefix_reqs(cfg, groups=1, per_group=4,
                               prefix_len=18, tail_lens=[6, 3, 5, 4])
    mns = [4, 3, 5, 2]
    refs = _refs(eng, reqs, mns)
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            check_invariants=True, prefix_cache=True)
    for i, (o, r) in enumerate(zip(outs, refs)):
        np.testing.assert_array_equal(
            o, r, err_msg=f"request {i} diverged with prefix cache on")
    px = stats.prefix
    assert px.hits >= 1 and px.cow_forks >= 1
    # computed prefill shrank by exactly the reused positions
    assert stats.prefill_tokens == sum(len(r) for r in reqs) \
        - px.hit_tokens
    # cache off: same tokens, no counters
    outs_off, stats_off = eng.serve(reqs, batch_slots=2,
                                    max_new_tokens=mns,
                                    prefill_chunk=CHUNK, page_size=PAGE)
    assert stats_off.prefix is None
    for o, r in zip(outs_off, refs):
        np.testing.assert_array_equal(o, r)


def test_prefix_cache_parity_under_eviction_pressure(stablelm):
    """A tight pool forces the LRU evictor to reclaim cached pages
    mid-run; parity and the teardown leak audit must survive the
    churn."""
    cfg, eng = stablelm
    reqs = _shared_prefix_reqs(cfg, groups=4, per_group=2,
                               prefix_len=16, tail_lens=[4, 6, 5],
                               seed=13)
    mns = [4] * len(reqs)
    refs = _refs(eng, reqs, mns)
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            num_pages=9, check_invariants=True,
                            prefix_cache=True)
    for i, (o, r) in enumerate(zip(outs, refs)):
        np.testing.assert_array_equal(
            o, r, err_msg=f"request {i} diverged under eviction")
    assert stats.prefix.evicted_pages > 0, \
        "tight pool never pressured the cache — gate unexercised"


@pytest.mark.slow
def test_prefix_cache_parity_quantized():
    """Cached KV written by a quantized (int8 packs) prefill is reused
    bit-identically — the cache composes with the quantized serving
    contract."""
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=MAX_LEN, packed=True,
                 quant="int8")
    reqs = _shared_prefix_reqs(cfg, groups=1, per_group=3,
                               prefix_len=18, tail_lens=[5, 3, 6],
                               seed=14)
    mns = [4, 3, 5]
    refs = _refs(eng, reqs, mns)
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            prefix_cache=True)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    assert stats.prefix.hits >= 1 and stats.quant == "int8"


@pytest.mark.slow
def test_parity_quantized_megastep():
    """Quantized decode through the lane (split-K plans on quant packs)
    stays bit-identical to per-request generate across megastep depth."""
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    eng = Engine(cfg, model_zoo.build(cfg), max_len=MAX_LEN, packed=True,
                 quant="int8")
    reqs = _requests(cfg, [5, 17, 8], seed=11)
    mns = [4, 6, 3]
    refs = _refs(eng, reqs, mns)
    for depth in (1, 4):
        outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                                prefill_chunk=CHUNK, page_size=PAGE,
                                megastep_depth=depth)
        for o, r in zip(outs, refs):
            np.testing.assert_array_equal(o, r)
        assert stats.quant == "int8"
