"""Runtime tests: train loop learns + checkpoints + resumes bitwise;
watchdog flags stragglers; serving engine equivalences."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.data import SyntheticLM, make_batches
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.runtime import fault_tolerance as ft
from repro.runtime import serve_loop, train_loop


@pytest.fixture(scope="module")
def small():
    cfg = model_zoo.reduced_config(model_zoo.get_config("deepseek-7b"))
    return cfg, model_zoo.build(cfg)


def test_train_loss_decreases(small, tmp_path):
    cfg, _ = small
    tc = TrainConfig(steps=8, learning_rate=2e-3, warmup_steps=1,
                     checkpoint_every=4)
    mesh = make_host_mesh()
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4)
    state, hist = train_loop.train(cfg, tc, mesh, make_batches(src),
                                   ckpt_dir=str(tmp_path), log_every=1)
    assert len(hist) == 8
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert int(state.step) == 8


def test_resume_is_bitwise_deterministic(small, tmp_path):
    """Fault-tolerance invariant: train 6 straight == train 3, checkpoint,
    restart, train 3 more — bit-identical params (data stream is a pure
    function of step, optimizer is deterministic)."""
    cfg, _ = small
    mesh = make_host_mesh()
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=2)

    tc6 = TrainConfig(steps=6, learning_rate=1e-3, warmup_steps=2,
                      checkpoint_every=100)
    s_straight, _ = train_loop.train(cfg, tc6, mesh, make_batches(src),
                                     log_every=100)

    tc3 = TrainConfig(steps=3, learning_rate=1e-3, warmup_steps=2,
                      checkpoint_every=3)
    d = str(tmp_path / "ck")
    train_loop.train(cfg, tc3, mesh, make_batches(src), ckpt_dir=d,
                     log_every=100)
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(d)
    like = train_loop.abstract_state(cfg, tc6)
    state, start = ft.resume_or_init(
        mgr, lambda: train_loop.init_state(cfg, tc6), like,
        shardings=train_loop.state_shardings(like, mesh))
    assert start == 3
    s_resumed, _ = train_loop.train(
        cfg, tc6, mesh, make_batches(src, start_step=start), state=state,
        start_step=start, log_every=100)
    for a, b in zip(jax.tree.leaves(s_straight.params),
                    jax.tree.leaves(s_resumed.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_num_microbatches():
    assert train_loop.num_microbatches(256, 16, 1) == 16
    assert train_loop.num_microbatches(256, 16, 16) == 1
    assert train_loop.num_microbatches(256, 32, 1) == 8
    assert train_loop.num_microbatches(1, 16, 1) == 1
    # non-dividing per_device rounds down to a divisor
    assert train_loop.num_microbatches(12, 1, 5) == 2


def test_watchdog_flags_injected_straggler():
    events = []
    wd = ft.StepWatchdog(factor=3.0, warmup=1,
                         on_straggler=events.append)
    for _ in range(5):
        wd.record(0.1)
    assert wd.record(1.0) is True           # 10x EMA
    assert len(events) == 1
    # EMA not poisoned by the straggler sample
    assert wd.ema < 0.2
    assert wd.record(0.1) is False


def test_graceful_shutdown_flag():
    import os
    import signal
    gs = ft.GracefulShutdown(signals=(signal.SIGUSR1,)).install()
    assert not gs.requested
    os.kill(os.getpid(), signal.SIGUSR1)
    assert gs.requested
    gs.uninstall()


def test_engine_packed_matches_raw(small):
    cfg, params = small
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 12)),
        jnp.int32)
    out_p, _ = serve_loop.Engine(cfg, params, max_len=64,
                                 packed=True).generate(prompts, 6)
    out_r, _ = serve_loop.Engine(cfg, params, max_len=64,
                                 packed=False).generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))


def test_engine_slot_pool_serves_all(small):
    cfg, params = small
    eng = serve_loop.Engine(cfg, params, max_len=64, packed=True)
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, cfg.vocab_size, rng.integers(3, 10))
            .astype(np.int32) for _ in range(5)]
    outs, stats = eng.serve(reqs, batch_slots=2, max_new_tokens=4,
                            prefill_chunk=8, page_size=8)
    assert len(outs) == 5
    assert all(o.shape == (4,) for o in outs)
    assert stats.decode_tokens == 5 * 4
    assert stats.prefill_tokens == sum(len(r) for r in reqs)
    # the legacy phase-locked loop still serves (the benchmark baseline)
    outs2, stats2 = eng.serve_chunked(reqs, batch_slots=2, prompt_len=12,
                                      max_new_tokens=4)
    assert len(outs2) == 5 and stats2.decode_tokens == 5 * 4
