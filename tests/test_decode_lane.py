"""Decode fast-lane gates: split-K kernel/oracle bitwise determinism,
the decode policy arm, decode M buckets, plan warmup, and the megastep
serving stats.

The split-K discipline is the paper's bit-exactness protocol extended
to the reduction dimension: for every split_k the recombined kernel
result must be BIT-IDENTICAL to ``kernels/ref.gemm_splitk`` — per-slice
blocked partials summed by the shared deterministic fixed-order tree —
for fp32 and both quantized formats, with and without fused epilogues.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import gemm
from repro.core import bitexact, packing
from repro.gemm import backends as B
from repro.kernels import panel_gemm as K
from repro.kernels import ref
from repro.quant import formats as F
from repro.quant import kernels as QK

BM, BN, BK = 8, 128, 128
SPLITS = (1, 2, 4, 8)


def _operands(split_k, n=BN, seed=0):
    rng = np.random.default_rng(seed)
    k = 2 * BK * split_k           # every slice carries a real K-carry
    x = jnp.asarray(rng.standard_normal((BM, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return x, w


# ------------------------------------------------- bitwise determinism
@pytest.mark.parametrize("split_k", SPLITS)
def test_splitk_kernel_bitwise_vs_oracle_fp32(split_k):
    x, w = _operands(split_k)
    y = K.panel_gemm_splitk(x, w, split_k=split_k, block_m=BM,
                            block_n=BN, block_k=BK, interpret=True)
    oracle = ref.gemm_splitk(x, w, BK, split_k)
    bitexact.assert_bit_identical(np.asarray(y), np.asarray(oracle),
                                  f"split_k={split_k}")


@pytest.mark.parametrize("fmt", ["int8", "ternary"])
@pytest.mark.parametrize("split_k", SPLITS)
def test_splitk_kernel_bitwise_vs_oracle_quant(fmt, split_k):
    x, w = _operands(split_k, seed=1)
    q, s = F.quantize(w, fmt)
    data = F.pack_ternary_codes(q) if fmt == "ternary" else q
    y = QK.quant_panel_gemm_splitk(x, data, s, weight_format=fmt,
                                   split_k=split_k, block_m=BM,
                                   block_n=BN, block_k=BK,
                                   interpret=True)
    oracle = ref.gemm_splitk(x, F.dequantize_padded(data, s, fmt), BK,
                             split_k)
    bitexact.assert_bit_identical(np.asarray(y), np.asarray(oracle),
                                  f"{fmt} split_k={split_k}")


@pytest.mark.parametrize("spec", [
    gemm.EpilogueSpec(bias=True),
    gemm.EpilogueSpec(act="silu", residual=True),
    gemm.EpilogueSpec(softcap=30.0),
    gemm.EpilogueSpec(glu="silu"),
])
def test_splitk_epilogue_composes_bitwise(spec):
    """Every EpilogueSpec applies on the COMBINED fp32 accumulator via
    the shared apply_epilogue — bit-identical to oracle + jnp epilogue."""
    split_k = 2
    rng = np.random.default_rng(2)
    k = 2 * BK * split_k
    n = 2 * BN if spec.glu else BN
    n_out = BN if spec.glu else n
    x = jnp.asarray(rng.standard_normal((BM, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    bias = (jnp.asarray(rng.standard_normal((n,)), jnp.float32)
            if spec.bias else None)
    res = (jnp.asarray(rng.standard_normal((BM, n_out)), jnp.float32)
           if spec.residual else None)
    y = K.panel_gemm_splitk(x, w, bias, res, split_k=split_k, block_m=BM,
                            block_n=BN, block_k=BK, epilogue=spec,
                            interpret=True)
    acc = ref.gemm_splitk(x, w, BK, split_k, out_dtype=jnp.float32)
    oracle = jax.jit(lambda a, b, r: K.apply_epilogue(
        a, spec, bias=b, residual=r).astype(jnp.float32))(acc, bias, res)
    bitexact.assert_bit_identical(np.asarray(y), np.asarray(oracle),
                                  f"epilogue={spec}")


def test_splitk_validate_plan_gates():
    """plan(validate=True) runs the split-K gate for fp32 and quant."""
    for fmt in ("fp32", "int8", "ternary"):
        p = gemm.plan(BM, BN, 4 * BK, block_m=BM, block_n=BN, block_k=BK,
                      split_k=4, decode=True, weight_format=fmt,
                      validate=True)
        assert p.validated and p.split_k == 4
        assert gemm.validate_plan(p)


def test_splitk_one_degenerates_to_blocked():
    x, w = _operands(1)
    a = ref.gemm_splitk(x, w, BK, 1)
    b = ref.gemm_blocked(x, w, BK)
    bitexact.assert_bit_identical(np.asarray(a), np.asarray(b),
                                  "split_k=1 vs blocked")


def test_splitk_combine_fixed_tree_order():
    """The combine is the static pairwise tree, not a left fold."""
    parts = [jnp.full((1, 1), float(v)) for v in (1e16, 1.0, 1.0, -1e16)]
    tree = np.asarray(gemm.splitk_combine(parts))[0, 0]
    # tree: (1e16 + 1) + (1 - 1e16) = 1e16 + (1 - 1e16) = 0.0
    # fold: ((1e16 + 1) + 1) - 1e16 = 0.0 too — distinguish with order
    parts2 = [jnp.full((1, 1), float(v)) for v in (1.0, 1e16, -1e16, 1.0)]
    tree2 = np.asarray(gemm.splitk_combine(parts2))[0, 0]
    # tree: (1 + 1e16) + (-1e16 + 1) = 1e16 + (1 - 1e16) = 0.0
    # fold: ((1 + 1e16) - 1e16) + 1 = 1.0
    assert tree == 0.0 and tree2 == 0.0
    # odd count: trailing partial rides up unpaired
    odd = [jnp.full((1, 1), float(v)) for v in (1.0, 2.0, 3.0)]
    assert np.asarray(gemm.splitk_combine(odd))[0, 0] == 6.0


# ---------------------------------------------- xla backend split path
def test_xla_splitk_run_bitwise_vs_slice_reference():
    rng = np.random.default_rng(3)
    n, k, s = 256, 1024, 4
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, k)), jnp.float32)
    pw = packing.pack(w, block_n=128, block_k=256)
    p = gemm.plan(4, n, k, backend="xla", block_n=128, block_k=256,
                  pack=gemm.PACK_PREPACKED, decode=True, split_k=s)
    y = gemm.execute(p, x, pw)
    ks = k // s
    parts = [jnp.dot(x[:, i * ks:(i + 1) * ks], w[i * ks:(i + 1) * ks],
                     preferred_element_type=jnp.float32)
             for i in range(s)]
    yref = jax.jit(lambda ps: gemm.splitk_combine(ps))(parts)
    bitexact.assert_bit_identical(np.asarray(y),
                                  np.asarray(yref.astype(y.dtype)),
                                  "xla split-K execute")


def test_execute_rejects_undivisible_split():
    w = jnp.zeros((512, 128), jnp.float32)
    pw = packing.pack(w, block_n=128, block_k=256)
    p = gemm.plan(4, 128, 512, backend="xla", block_n=128, block_k=256,
                  pack=gemm.PACK_PREPACKED, decode=True, split_k=2)
    assert p.split_k == 2          # 512 / 2 = 256-deep slices: fine
    with pytest.raises(ValueError):
        gemm.plan(4, 128, 512, block_n=128, block_k=256, decode=True,
                  split_k=4)      # 128-deep slices < block_k


# ------------------------------------------------------ decode policy arm
def test_decode_lane_scope_and_plan_keying():
    gemm.plan_cache_clear()
    with gemm.decode_lane():
        assert gemm.in_decode_lane()
        pd = gemm.plan(4, 1024, 4096)
    assert not gemm.in_decode_lane()
    pp = gemm.plan(4, 1024, 4096)
    assert pd.decode and not pp.decode
    assert pd.pack == gemm.PACK_PREPACKED       # decode arm forces prepack
    assert pp.pack == gemm.PACK_PERCALL         # fine lever's default
    assert pd.block_m == 8
    # distinct cache entries for the same (m, n, k)
    assert gemm.plan_cache_info().misses >= 2


def test_decode_split_k_is_m_independent():
    """The slice map must be a pure function of (n, k, format): serve
    decodes at M = slots, generate at M = batch — same split, or the
    two paths' tokens diverge bitwise.  (On the panel-grid backends —
    occupancy is a grid property, so the shape-agnostic xla backend
    keeps split_k = 1 by policy.)"""
    with gemm.decode_lane():
        plans = [gemm.plan(m, 256, 2048, backend="interpret")
                 for m in (1, 2, 4, 8, 16)]
    assert len({p.split_k for p in plans}) == 1
    # narrow-N deep-K decode shapes actually engage the reduction lever
    assert plans[0].split_k > 1
    assert all(p.block_m == 8 for p in plans)   # pinned skinny panel


def test_decode_split_k_only_on_grid_backends():
    """The occupancy model scores kernel-grid panels; the xla backend
    has no grid, and the restructure measured a wash-to-loss on CPU —
    policy keeps split_k = 1 there (explicit split_k= still works)."""
    with gemm.decode_lane():
        p_xla = gemm.plan(4, 256, 2048, backend="xla")
        p_krn = gemm.plan(4, 256, 2048, backend="interpret")
    assert p_xla.split_k == 1 and p_xla.decode
    assert p_krn.split_k > 1
    p_exp = gemm.plan(4, 256, 2048, backend="xla", decode=True,
                      split_k=2)
    assert p_exp.split_k == 2


def test_decode_arm_prefill_shapes_unsplit():
    """The prefill row panel keeps split_k == 1 (occupancy already comes
    from the (M/bm, N/bn) grid there)."""
    p = gemm.plan(128, 1024, 4096)
    assert p.split_k == 1 and not p.decode


def test_decode_buckets():
    assert [gemm.bucket_m(m, decode=True) for m in (1, 2, 3, 4, 5, 8)] \
        == [1, 2, 4, 4, 8, 8]
    # beyond the decode buckets: falls through to the prefill ladder
    assert gemm.bucket_m(9, decode=True) == 16
    assert gemm.bucket_m(129, decode=True) == 256
    # prefill bucketing unchanged (the aliasing the decode buckets fix)
    assert [gemm.bucket_m(m) for m in (1, 2, 4, 8)] == [8, 8, 8, 8]
    with pytest.raises(ValueError):
        gemm.bucket_m(0, decode=True)


def test_scheduler_scores_splitk_occupancy():
    """The napkin model: split-K restores reduction-side occupancy at
    skinny M / narrow N, and charges the combine cost."""
    from repro.core import scheduler
    base = scheduler.plan(8, 256, 2048, block_m=8, block_n=128,
                          block_k=512, num_cores=8)
    split = scheduler.plan(8, 256, 2048, block_m=8, block_n=128,
                           block_k=512, num_cores=8, split_k=4)
    assert split.panels == 4 * base.panels
    assert split.occupancy > base.occupancy
    assert split.hbm_bytes > base.hbm_bytes      # partials round-trip
    assert split.t_pred < base.t_pred


def test_vmem_budget_covers_partials_slab():
    base = K.vmem_bytes(8, 512, 2048)
    split = K.vmem_bytes(8, 512, 2048, split_k=8)
    assert split == base + 8 * 8 * 512 * 4
    # _fit_vmem sees the slab: a triple near the budget clamps under
    # a deep split where it stood unsplit
    from repro.gemm.policy import _fit_vmem
    bm, bn, bk, clamped = _fit_vmem(128, 512, 2048, "float32", None)
    assert not clamped
    assert K.vmem_bytes(bm, bn, bk, split_k=64) > K.VMEM_BUDGET


# --------------------------------------------------------- plan warmup
@pytest.fixture(scope="module")
def packed_engine():
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    return cfg, Engine(cfg, model_zoo.build(cfg), max_len=48, packed=True)


def test_warmup_plans_makes_first_tick_hot(packed_engine):
    cfg, eng = packed_engine
    t = eng.warmup_plans(batch_slots=2, prefill_chunk=8, page_size=8,
                         megastep_depth=4)
    assert {"prefill_chunk", "decode_step", "decode_megastep",
            "decode_bucket_plans", "plan_cache"} <= set(t)
    # the decode bucket ladder pre-resolved plans for every packed
    # weight at every DECODE_M_BUCKETS width
    assert t["decode_bucket_plans"] > 0
    misses0 = gemm.plan_cache_info().misses
    from repro.core.packing import PackedWeight
    import jax
    for leaf in jax.tree.leaves(
            eng.params,
            is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(leaf, PackedWeight):
            for b in gemm.DECODE_M_BUCKETS:
                gemm.plan_for_packed(b, leaf, decode=True)
    assert gemm.plan_cache_info().misses == misses0
    misses = gemm.plan_cache_info().misses
    rng = np.random.default_rng(5)
    reqs = [rng.integers(1, cfg.vocab_size, l).astype(np.int32)
            for l in (5, 11)]
    outs, _ = eng.serve(reqs, batch_slots=2, max_new_tokens=3,
                        prefill_chunk=8, page_size=8, megastep_depth=4)
    assert gemm.plan_cache_info().misses == misses, \
        "first serving tick resolved a plan warmup should have owned"
    refs = [np.asarray(eng.generate(jnp.asarray(r)[None], 3)[0][0])
            for r in reqs]
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_warmup_rejects_stub_frontends():
    class FakeCfg:
        modality = "image"
    from repro.runtime.serve_loop import Engine
    eng = object.__new__(Engine)
    eng.cfg = FakeCfg()
    with pytest.raises(NotImplementedError):
        Engine.warmup_plans(eng, batch_slots=2)
