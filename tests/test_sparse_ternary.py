"""Sparse-ternary fast-path gates: the compressed zero-group layout
(occupancy bitmap + dense-packed survivor groups + group-offset index),
its exact round-trip law, the sparse group-walk kernel's bitwise
contract vs the dense ternary kernel and the blocked oracle, the
density-bucketed policy arm (plan keys, store round-trip, split-K
rejection, VMEM budget), roofline honesty, ledger density columns, and
serve == generate parity on a group-sparse ternary engine.

The round-trip property runs under hypothesis when installed and falls
back to a deterministic seeded sweep otherwise (same discipline as
test_quant.py)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import gemm as G
from repro.core import bitexact, packing
from repro.gemm.execute import PlanMismatchError, execute
from repro.kernels import panel_gemm as K
from repro.quant import formats as F
from repro.quant import kernels as QK
from repro.quant import ledger

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

GK = F.GROUP_K
RNG = np.random.default_rng(31)


@pytest.fixture(autouse=True)
def _fresh_cache():
    G.plan_cache_clear()
    yield
    G.plan_cache_clear()


def _group_sparse(k, n, zero_groups, seed=0, stacked=0, scale=0.02):
    """A weight with the given whole GROUP_K K-groups zeroed (per layer
    when stacked): the construction every gate in this file runs on."""
    r = np.random.default_rng(seed)
    shape = (stacked, k, n) if stacked else (k, n)
    w = (r.standard_normal(shape) * scale).astype(np.float32)
    for g in zero_groups:
        w[..., g * GK:min((g + 1) * GK, k), :] = 0.0
    return jnp.asarray(w)


# ----------------------------------------------------- round-trip law
def _roundtrip(k, n, seed, stacked, zero_frac):
    r = np.random.default_rng(seed)
    kg = -(-k // GK)
    z = int(zero_frac * kg)
    groups = r.choice(kg, size=min(z, max(0, k // GK)), replace=False) \
        if z else []
    w = _group_sparse(k, n, groups, seed=seed, stacked=stacked)
    qpw = packing.pack(w, quant="ternary", sparse=False)
    spw = F.compress_ternary(qpw)
    back = F.decompress_ternary(spw)
    # bit-for-bit the dense pack the sparse one was built from
    np.testing.assert_array_equal(np.asarray(back.data),
                                  np.asarray(qpw.data))
    np.testing.assert_array_equal(np.asarray(back.scales),
                                  np.asarray(qpw.scales))
    assert (back.n, back.k) == (qpw.n, qpw.k)
    assert 0.0 <= spw.density <= 1.0
    assert spw.density_bucket == F.density_bucket_of(1.0 - spw.density)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(k=st.integers(1, 400), n=st.integers(1, 90),
           seed=st.integers(0, 2**31 - 1),
           stacked=st.sampled_from([0, 2]),
           zero_frac=st.floats(0.0, 1.0))
    def test_sparse_roundtrip_property(k, n, seed, stacked, zero_frac):
        _roundtrip(k, n, seed, stacked, zero_frac)
else:
    def test_sparse_roundtrip_property():
        # deterministic sweep: odd dims, group tails, all-zero,
        # all-dense, stacked layers
        cases = [(1, 1, 0.0), (127, 5, 1.0), (128, 64, 0.5),
                 (129, 31, 0.25), (255, 130, 0.8), (300, 60, 0.4),
                 (384, 17, 1.0), (257, 3, 0.0)]
        for i, (k, n, zf) in enumerate(cases):
            _roundtrip(k, n, 2000 + i, (0, 2)[i % 2], zf)


def test_all_zero_weight_compresses_to_empty_slab():
    w = jnp.zeros((256, 64), jnp.float32)
    spw = packing.pack(w, quant="ternary", sparse=True)
    assert isinstance(spw, F.SparseTernaryPackedWeight)
    assert spw.group_index == ()
    assert spw.density == 0.0 and spw.density_bucket == 9
    y = np.asarray(QK.sparse_ref(jnp.ones((4, 256)), spw))
    assert np.all(y == 0.0)


def test_auto_arm_thresholds_and_forced_layouts():
    # below threshold (dense weight): auto keeps dense
    w_dense = _group_sparse(512, 64, [], seed=3)
    assert not isinstance(packing.pack(w_dense, quant="ternary"),
                          F.SparseTernaryPackedWeight)
    # above threshold: auto compresses
    w_sp = _group_sparse(512, 64, [0, 1], seed=3)
    assert isinstance(packing.pack(w_sp, quant="ternary"),
                      F.SparseTernaryPackedWeight)
    # sparse=False pins dense even above threshold
    assert not isinstance(
        packing.pack(w_sp, quant="ternary", sparse=False),
        F.SparseTernaryPackedWeight)
    # the layout is ternary-only, and sparse= requires quant=
    with pytest.raises(F.QuantFormatError):
        packing.pack(w_sp, quant="int8", sparse=True)
    with pytest.raises(ValueError, match="requires quant='ternary'"):
        packing.pack(w_sp, sparse=True)


# ------------------------------------------------- kernel bitwise gate
@pytest.mark.parametrize("spec,has_bias,has_res", [
    (None, False, False),
    (G.EpilogueSpec(bias=True), True, False),
    (G.EpilogueSpec(act="silu", residual=True), False, True),
    (G.EpilogueSpec(bias=True, glu="silu", residual=True), True, True),
])
def test_sparse_kernel_bitwise_vs_dense_and_oracle(spec, has_bias,
                                                   has_res):
    """The sparse walk == the dense ternary kernel at block_k=GROUP_K
    on the same codes == the blocked oracle, bitwise, across the
    epilogue grid (glu included)."""
    k, n = 384, 128
    glu = spec is not None and spec.glu is not None
    n_log = n * 2 if glu else n
    w = _group_sparse(k, n_log, [1], seed=7)
    if glu:
        qpw = F.quantize_pack_fused([w[:, :n], w[:, n:]], "ternary",
                                    block_n=64, block_k=GK,
                                    sparse=False)
    else:
        qpw = packing.pack(w, block_n=64, block_k=GK, quant="ternary",
                           sparse=False)
    spw = F.compress_ternary(qpw)
    x = jnp.asarray(RNG.standard_normal((16, k)).astype(np.float32))
    bias = jnp.asarray(RNG.standard_normal(n).astype(np.float32)) \
        if has_bias else None
    if glu and bias is not None:
        bias = jnp.concatenate([bias, bias])
    res = jnp.asarray(RNG.standard_normal((16, n)).astype(np.float32)) \
        if has_res else None

    y_sparse = QK.sparse_quant_panel_gemm(
        x, spw.data, spw.scales, bias, res,
        sparse_layout=spw.sparse_layout, block_m=16, block_n=64,
        epilogue=spec, interpret=True)
    y_dense = QK.quant_panel_gemm(
        x, qpw.data, qpw.scales, bias, res, weight_format="ternary",
        block_m=16, block_n=64, block_k=GK, epilogue=spec,
        interpret=True)
    y_ref = QK.sparse_ref(x, spw, epilogue=spec, bias=bias,
                          residual=res)
    bitexact.assert_bit_identical(np.asarray(y_sparse),
                                  np.asarray(y_dense),
                                  "sparse vs dense ternary kernel")
    bitexact.assert_bit_identical(np.asarray(y_sparse),
                                  np.asarray(y_ref),
                                  "sparse kernel vs blocked oracle")


# ------------------------------------------------ plan/policy/execute
def test_plan_key_carries_density_bucket_and_is_stable():
    w = _group_sparse(512, 64, [0, 2], seed=11)
    spw1 = packing.pack(w, quant="ternary", sparse=True)
    spw2 = packing.pack(w, quant="ternary", sparse=True)
    assert spw1.density_bucket == spw2.density_bucket == 5
    p1 = G.plan_for_packed(32, spw1, backend="xla")
    p2 = G.plan_for_packed(32, spw2, backend="xla")
    assert p1 is p2                       # same key -> cached plan hit
    assert p1.density_bucket == 5 and p1.sparse
    # dense pack of the same weight resolves a DIFFERENT plan
    qpw = packing.pack(w, block_n=spw1.block_n, block_k=spw1.block_k,
                       quant="ternary", sparse=False)
    pd = G.plan_for_packed(32, qpw, backend="xla")
    assert pd.density_bucket == -1 and not pd.sparse
    assert pd is not p1


def test_sparse_plan_rejects_split_k_and_non_ternary():
    with pytest.raises(ValueError, match="split_k"):
        G.plan(32, 64, 512, weight_format="ternary", split_k=2,
               density_bucket=5)
    with pytest.raises(ValueError, match="ternary"):
        G.plan(32, 64, 512, weight_format="int8", density_bucket=5)


def test_execute_mismatch_checks():
    w = _group_sparse(512, 64, [0, 2], seed=13)
    spw = packing.pack(w, quant="ternary", sparse=True)
    qpw = packing.pack(w, block_n=spw.block_n, block_k=spw.block_k,
                       quant="ternary", sparse=False)
    x = jnp.asarray(RNG.standard_normal((8, 512)).astype(np.float32))
    sp = G.plan_for_packed(8, spw, backend="xla")
    dp = G.plan_for_packed(8, qpw, backend="xla")
    # matched pairs execute; crossed pairs are PlanMismatch
    execute(sp, x, spw)
    execute(dp, x, qpw)
    with pytest.raises(PlanMismatchError):
        execute(sp, x, qpw)               # sparse plan, dense pack
    with pytest.raises(PlanMismatchError):
        execute(dp, x, spw)               # dense plan, sparse pack


def test_sparse_execute_parity_across_backends():
    w = _group_sparse(640, 96, [0, 3], seed=17)
    spw = packing.pack(w, quant="ternary", sparse=True)
    qpw = packing.pack(w, block_n=spw.block_n, block_k=spw.block_k,
                       quant="ternary", sparse=False)
    x = jnp.asarray(RNG.standard_normal((8, 640)).astype(np.float32))
    ip = G.plan_for_packed(8, spw, backend="interpret")
    y_i = np.asarray(execute(ip, x, spw))
    bitexact.assert_bit_identical(
        y_i, np.asarray(QK.sparse_ref(x, spw))[:, :spw.n],
        "planned sparse interpret vs oracle")
    xp = G.plan_for_packed(8, spw, backend="xla")
    y_x = np.asarray(execute(xp, x, spw))
    y_d = np.asarray(execute(G.plan_for_packed(8, qpw, backend="xla"),
                             x, qpw))
    np.testing.assert_allclose(y_x, y_d, rtol=2e-5, atol=1e-6)


def test_plan_store_roundtrips_density_bucket(tmp_path):
    path = tmp_path / "plans.json"
    store = G.PlanStore(path)
    w = _group_sparse(512, 64, [0, 1], seed=19)
    spw = packing.pack(w, quant="ternary", sparse=True)
    with G.use_plan_store(store):
        p = G.plan_for_packed(16, spw, backend="xla")
    store.save()
    G.plan_cache_clear()
    store2 = G.PlanStore.load(path)
    with G.use_plan_store(store2):
        p2 = G.plan_for_packed(16, spw, backend="xla")
    assert p2.density_bucket == p.density_bucket >= 0
    assert store2.hits >= 1


# -------------------------------------------------- models of the cost
def test_vmem_budget_sparse_monotone_and_group_pinned():
    base = K.vmem_bytes(128, 128, 512, weight_format="ternary")
    s1 = K.vmem_bytes(128, 128, 512, weight_format="ternary",
                      sparse_groups=4, sparse_panels=2)
    s2 = K.vmem_bytes(128, 128, 512, weight_format="ternary",
                      sparse_groups=16, sparse_panels=2)
    # the sparse walk tiles at GROUP_K regardless of block_k, so its
    # x/w/scales tiles are never LARGER than the dense block's; the
    # index slab grows with the occupied-group count
    assert s1 < base + 4 * 4 * (1 + 2) + 1
    assert s2 > s1


def test_roofline_scales_with_density():
    from repro.roofline import gemm_roofline
    t1 = gemm_roofline(128, 4096, 4096, weight_format="ternary")
    t3 = gemm_roofline(128, 4096, 4096, weight_format="ternary",
                       weight_density=0.3)
    assert t3 < t1


def test_sparse_threshold_is_sane():
    th = G.sparse_threshold()
    assert 0.0 < th < 1.0
    # the shipped policy crossover sits at or above the napkin number
    assert F.SPARSE_DENSITY_THRESHOLD >= th


def test_ledger_records_pack_density():
    ledger.clear()
    w = _group_sparse(512, 64, [0, 1], seed=23)
    packing.pack(w, quant="ternary", sparse=True)
    ent = ledger.lookup(64, 512, "ternary")
    assert ent is not None and ent.sparse and ent.density == 0.5
    assert "density" in ent.row()
    ledger.clear()
    packing.pack(w, quant="ternary", sparse=False)
    ent = ledger.lookup(64, 512, "ternary")
    assert ent is not None and not ent.sparse and ent.density == 1.0
    ledger.clear()


# ------------------------------------------------------- serving gate
def test_sparse_engine_serve_matches_generate():
    """A ternary engine whose projections are genuinely group-sparse
    auto-crosses to the compressed layout, serves with parity to
    per-request generate, and surfaces the pack stats."""
    from repro.models import model_zoo
    from repro.runtime.serve_loop import Engine
    cfg = model_zoo.reduced_config(model_zoo.get_config("stablelm-3b"))
    cfg = dataclasses.replace(cfg, d_model=256, d_ff=256,
                              name=cfg.name + "-sparse")
    params = model_zoo.build(cfg)

    def sparsify(path, x):
        if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[-2] >= 256:
            y = np.asarray(x).copy()
            y[..., 0:GK, :] = 0.0
            return jnp.asarray(y)
        return x
    params = jax.tree_util.tree_map_with_path(sparsify, params)
    eng = Engine(cfg, params, max_len=48, packed=True, quant="ternary")
    n_sparse = sum(
        1 for leaf in jax.tree.leaves(
            eng.params,
            is_leaf=lambda v: isinstance(v, F.SparseTernaryPackedWeight))
        if isinstance(leaf, F.SparseTernaryPackedWeight))
    assert n_sparse > 0

    rng = np.random.default_rng(5)
    reqs = [rng.integers(1, cfg.vocab_size, int(ln)).astype(np.int32)
            for ln in (5, 9, 3)]
    mns = [4, 3, 5]
    refs = [np.asarray(eng.generate(jnp.asarray(r)[None], m)[0][0])
            for r, m in zip(reqs, mns)]
    outs, sstats = eng.serve(reqs, batch_slots=2, max_new_tokens=mns,
                             prefill_chunk=8, page_size=8)
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)
    assert sstats.quant == "ternary"
    assert sstats.quant_sparse_packs == n_sparse
    assert sstats.quant_density is not None and sstats.quant_density < 1.0
    _, gstats = eng.generate(jnp.asarray(reqs[0])[None], 2)
    assert gstats.quant_sparse_packs == n_sparse
    assert gstats.quant_density == sstats.quant_density
