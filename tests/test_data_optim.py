"""Data pipeline + optimizer tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp

from repro import optim
from repro.data import (SyntheticLM, TokenFileDataset, make_batches,
                        write_token_file)


# ------------------------------------------------------------------ data
def test_synthetic_deterministic_per_step():
    src = SyntheticLM(vocab_size=100, seq_len=16, batch_size=3, seed=5)
    b1, b2 = src.batch(7), src.batch(7)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = src.batch(8)
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_labels_are_shifted_inputs():
    src = SyntheticLM(vocab_size=50, seq_len=8, batch_size=2)
    b = src.batch(0)
    assert b["inputs"].shape == b["labels"].shape == (2, 8)
    # labels[t] is the token after inputs[t] in the underlying stream
    assert np.array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_token_range():
    src = SyntheticLM(vocab_size=37, seq_len=64, batch_size=4)
    b = src.batch(3)
    assert b["inputs"].min() >= 0 and b["inputs"].max() < 37


def test_token_file_dataset(tmp_path):
    path = str(tmp_path / "toks.bin")
    write_token_file(path, np.arange(10_000) % 251)
    ds = TokenFileDataset(path, seq_len=32, batch_size=4)
    b = ds.batch(0)
    assert b["inputs"].shape == (4, 32)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])
    # host sharding: two processes see disjoint stripes
    d0 = TokenFileDataset(path, seq_len=32, batch_size=4,
                          process_index=0, process_count=2)
    d1 = TokenFileDataset(path, seq_len=32, batch_size=4,
                          process_index=1, process_count=2)
    assert d0._lo != d1._lo


def test_make_batches_resume_replays_stream():
    src = SyntheticLM(vocab_size=100, seq_len=8, batch_size=2)
    run1 = [b for _, b in zip(range(5), (b for _, b in
                                         make_batches(src)))]
    it = make_batches(src, start_step=3)
    step, b3 = next(it)
    assert step == 3
    np.testing.assert_array_equal(b3["inputs"], run1[3]["inputs"])


def test_make_batches_embed_mode():
    src = SyntheticLM(vocab_size=100, seq_len=8, batch_size=2)
    _, b = next(make_batches(src, embed_dim=16))
    assert b["inputs"].shape == (2, 8, 16)
    assert b["inputs"].dtype == np.float32
    assert b["labels"].shape == (2, 8)


# ----------------------------------------------------------------- optim
def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((4, 4)), jnp.float32)
    params = {"w": jnp.zeros((4, 4), jnp.float32),
              "b": jnp.zeros((4,), jnp.float32)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)
    return params, loss, target


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    params, loss, target = _quad_problem()
    opt = optim.make(name, lambda s: 0.05, weight_decay=0.0)
    state = opt.init(params)
    for step in range(400):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params, step)
    assert float(loss(params)) < 0.05


def test_grad_clip_bounds_update():
    g = {"w": jnp.full((8, 8), 100.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) > 99
    assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-4


def test_schedule_shape():
    lr = optim.warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(5)) == pytest.approx(5e-4)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)  # floor 0.1
    assert float(lr(55)) < float(lr(10))


@settings(max_examples=30, deadline=None)
@given(shape=st.sampled_from([(3,), (4, 5), (2, 3, 4)]),
       name=st.sampled_from(["adamw", "adafactor"]))
def test_optimizer_update_is_finite_and_shaped(shape, name):
    """Property: any gradient keeps params finite and shaped."""
    rng = np.random.default_rng(0)
    p = {"x": jnp.asarray(rng.standard_normal(shape), jnp.float32)}
    g = {"x": jnp.asarray(rng.standard_normal(shape) * 10, jnp.float32)}
    opt = optim.make(name, lambda s: 1e-2)
    new_p, _, stats = opt.update(g, opt.init(p), p, 3)
    assert new_p["x"].shape == shape
    assert np.all(np.isfinite(np.asarray(new_p["x"])))
    assert np.isfinite(float(stats["grad_norm"]))


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((7,))}
    st_ = optim.adafactor(lambda s: 1e-3).init(p)
    assert st_["f"]["w"]["vr"].shape == (64,)
    assert st_["f"]["w"]["vc"].shape == (32,)
    assert st_["f"]["v"]["v"].shape == (7,)
    # stacked 3-D params factor over the last two dims, per layer
    p3 = {"w": jnp.zeros((4, 8, 16))}
    st3 = optim.adafactor(lambda s: 1e-3).init(p3)
    assert st3["f"]["w"]["vr"].shape == (4, 8)
    assert st3["f"]["w"]["vc"].shape == (4, 16)
