"""Overlapped-collective-matmul tests.

These need >1 device, and the XLA device count is locked at first jax
init — so each test runs a small script in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the conftest-free
pattern the brief requires: smoke tests see 1 device, only these scripts
see 8)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(body: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    script = (
        "import jax, numpy as np, jax.numpy as jnp\n"
        "from jax.sharding import NamedSharding, PartitionSpec as P\n"
        "from repro import compat\n"
        "from repro.parallel import collectives as C\n"
        "auto = compat.axis_type_auto()\n"
        "mesh = compat.make_mesh((2, 4), ('data', 'model'),\n"
        "    axis_types=auto and (auto,) * 2)\n"
        + body)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_ag_matmul_matches_dense():
    out = run_script("""
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P(None, 'model')))
ws = jax.device_put(w, NamedSharding(mesh, P(None, 'model')))
y = C.ag_matmul(xs, ws, mesh=mesh, axis='model')
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                           rtol=1e-5, atol=1e-5)
print('ag ok', y.shape)
""")
    assert "ag ok" in out


@pytest.mark.slow
def test_matmul_rs_matches_dense():
    out = run_script("""
rng = np.random.default_rng(1)
x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P(None, 'model')))
ws = jax.device_put(w, NamedSharding(mesh, P('model', None)))
y = C.matmul_rs(xs, ws, mesh=mesh, axis='model')
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                           rtol=1e-5, atol=1e-5)
print('rs ok', y.shape)
""")
    assert "rs ok" in out


@pytest.mark.slow
def test_overlap_hlo_has_permutes_not_allgather():
    """The point of the decomposition: the compiled HLO contains
    collective-permute ring hops interleaved with per-panel dots, not a
    monolithic all-gather before one big dot."""
    out = run_script("""
xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((64, 32), jnp.float32)
f = jax.jit(lambda x, w: C.ag_matmul(x, w, mesh=mesh, axis='model'),
            in_shardings=(NamedSharding(mesh, P(None, 'model')),
                          NamedSharding(mesh, P(None, 'model'))))
txt = f.lower(xs, ws).compile().as_text()
assert 'collective-permute' in txt, 'no ring hops found'
print('n_permute_lines', sum('collective-permute(' in l
                             for l in txt.splitlines()))
""")
    assert "n_permute_lines" in out


@pytest.mark.slow
def test_sharded_train_step_runs_on_8_devices():
    """End-to-end SPMD integration: one real train step on a 2x4 mesh
    with FSDP+TP shardings actually executing (not just lowering)."""
    out = run_script("""
from repro.models import model_zoo
from repro.configs.base import TrainConfig
from repro.runtime import train_loop
cfg = model_zoo.reduced_config(model_zoo.get_config('deepseek-7b'))
import dataclasses
cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=128)
tc = TrainConfig(steps=1, warmup_steps=0, learning_rate=1e-3)
step = train_loop.make_train_step(cfg, tc, mesh, donate=False)
state = jax.device_put(train_loop.init_state(cfg, tc),
                       train_loop.state_shardings(
                           train_loop.abstract_state(cfg, tc), mesh))
rng = np.random.default_rng(0)
batch = {'inputs': jnp.asarray(rng.integers(0, 128, (8, 32)), jnp.int32),
         'labels': jnp.asarray(rng.integers(0, 128, (8, 32)), jnp.int32)}
new_state, metrics = step(state, batch)
assert np.isfinite(float(metrics['loss']))
print('spmd step ok', float(metrics['loss']))
""")
    assert "spmd step ok" in out
