"""Sharding-rule tests: divisibility guards, spec validity on the
production meshes (specs only — no 512-device runtime needed), and
hypothesis properties of fit_spec."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

import jax
from jax.sharding import PartitionSpec as P

from repro.models import model_zoo
from repro.parallel import sharding as Sh
from repro.runtime import train_loop
from repro.configs.base import TrainConfig


class FakeMesh:
    """Shape-only stand-in: sharding.py touches mesh.shape exclusively,
    so production-mesh specs are testable without 512 devices."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.size = int(np.prod(list(axes.values())))


SINGLE = FakeMesh(data=16, model=16)
MULTI = FakeMesh(pod=2, data=16, model=16)


def _check_tree(tree, specs, mesh):
    leaves = jax.tree.leaves(tree)
    spec_leaves = jax.tree.leaves(specs,
                                  is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        used = []
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for nm in names:
                assert nm not in used, f"axis {nm} reused in {spec}"
                used.append(nm)
            size = int(np.prod([mesh.shape[nm] for nm in names]))
            assert leaf.shape[d] % size == 0, (leaf.shape, d, spec)


@pytest.mark.parametrize("arch", model_zoo.list_archs())
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisible_every_arch(arch, mesh):
    cfg = model_zoo.get_config(arch)
    params = model_zoo.abstract_params(cfg)
    _check_tree(params, Sh.param_specs(params, mesh), mesh)


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-v3-671b",
                                  "mamba2-370m", "hymba-1.5b"])
def test_cache_specs_divisible(arch):
    from repro.models import transformer
    cfg = model_zoo.get_config(arch)
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, 128, 1024))
    _check_tree(cache, Sh.cache_specs(cache, SINGLE), SINGLE)


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b"])
def test_state_specs_divisible(arch):
    cfg = model_zoo.get_config(arch)
    tc = TrainConfig()
    state = train_loop.abstract_state(cfg, tc)
    specs = train_loop.state_shardings.__wrapped__ \
        if hasattr(train_loop.state_shardings, "__wrapped__") else None
    # exercise the spec computation path without NamedSharding (FakeMesh):
    pspecs = Sh.param_specs(state.params, SINGLE)
    _check_tree(state.params, pspecs, SINGLE)


def test_tp_dims_sharded_over_model():
    """The big matmul dims must actually be model-sharded (not silently
    replicated) for the archs where they divide."""
    cfg = model_zoo.get_config("deepseek-7b")
    params = model_zoo.abstract_params(cfg)
    specs = Sh.param_specs(params, SINGLE)
    attn = specs["layers"]["attn"]
    assert attn["wq"] == P(None, "data", "model")
    assert attn["wo"] == P(None, "model", "data")
    ffn = specs["layers"]["ffn"]
    assert ffn["w_gate"] == P(None, "data", "model")
    assert ffn["w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")


def test_moe_experts_sharded():
    cfg = model_zoo.get_config("qwen3-moe-30b-a3b")
    params = model_zoo.abstract_params(cfg)
    specs = Sh.param_specs(params, SINGLE)
    moe = specs["layers"]["moe"]
    assert moe["wi_gate"][1] == "model"     # (L, E→model, d→data, f)
    assert moe["wi_gate"][2] == "data"
    assert moe["wo"][1] == "model"


def test_nondivisible_falls_back_to_replication():
    # hymba: 25 heads * 64 = 1600; 1600 % 256 != 0 on a (data=16, model=16)
    # flat dim IS divisible by 16 → stays sharded; vocab 32001 is prime-ish
    # → must replicate.
    cfg = model_zoo.get_config("hymba-1.5b")
    params = model_zoo.abstract_params(cfg)
    specs = Sh.param_specs(params, SINGLE)
    assert specs["embed"][0] is None            # 32001 not divisible
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")


def test_batch_spec_prefix():
    assert Sh.batch_spec(256, MULTI) == P(("pod", "data"), None)
    assert Sh.batch_spec(2, MULTI) == P(("pod",), None) \
        or Sh.batch_spec(2, MULTI) == P("pod", None)
    assert Sh.batch_spec(1, MULTI) == P(None, None)
    assert Sh.batch_spec(32, SINGLE) == P(("data",), None) \
        or Sh.batch_spec(32, SINGLE) == P("data", None)


@settings(max_examples=200, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    axes=st.lists(st.sampled_from([None, "data", "model", "pod",
                                   ("pod", "data")]),
                  min_size=1, max_size=4),
)
def test_fit_spec_always_legal(dims, axes):
    """Property: fit_spec output always divides and never reuses an axis
    within one dim entry."""
    spec = Sh.fit_spec(P(*axes[:len(dims)]), tuple(dims), MULTI)
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([MULTI.shape[nm] for nm in names]))
        assert dims[d] % size == 0


def test_pack_for_inference_specs_follow_raw_weights():
    cfg = model_zoo.get_config("deepseek-7b")
    raw = model_zoo.abstract_params(cfg)
    packed = jax.eval_shape(
        lambda p: model_zoo.pack_for_inference(cfg, p), raw)
    specs = Sh.param_specs(packed, SINGLE)
    _check_tree(packed, specs, SINGLE)
    # the fused QKV pack must inherit the wqkv rule (column-sharded like
    # its parts), and the glu pack the w_gate_up rule
    pw_spec = jax.tree.leaves(
        specs["layers"]["attn"]["wqkv"],
        is_leaf=lambda x: isinstance(x, P))[0]
    assert pw_spec == P(None, "data", "model")
    gu_spec = jax.tree.leaves(
        specs["layers"]["ffn"]["w_gate_up"],
        is_leaf=lambda x: isinstance(x, P))[0]
    assert gu_spec == P(None, "data", "model")
    # the --no-fusion escape hatch keeps the per-projection rules
    unfused = jax.eval_shape(
        lambda p: model_zoo.pack_for_inference(cfg, p, fuse=False), raw)
    uspecs = Sh.param_specs(unfused, SINGLE)
    _check_tree(unfused, uspecs, SINGLE)
    pw_spec = jax.tree.leaves(
        uspecs["layers"]["attn"]["wq"],
        is_leaf=lambda x: isinstance(x, P))[0]
    assert pw_spec == P(None, "data", "model")
